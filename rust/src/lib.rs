//! # SsNAL-EN — Semi-smooth Newton Augmented Lagrangian method for the Elastic Net
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *An Efficient Semi-smooth Newton Augmented Lagrangian Method for Elastic Net*
//! (Boschi, Reimherr, Chiaromonte, 2020).
//!
//! The narrative architecture map — layer structure, the dense/CSC-sparse
//! [`linalg::DesignStorage`] dispatch, the pool/shard threading model and
//! its bitwise-invariance contract, the warm Newton workspace — lives in
//! `docs/ARCHITECTURE.md` at the repository root; this page and the module
//! docs are the reference.
//!
//! ## Quickstart
//!
//! The [`api`] module is the crate's canonical surface: a validated
//! [`Design`], a builder-style [`EnetModel`], and a warm [`Fit`] session.
//!
//! ```
//! use ssnal_en::{Design, EnetModel};
//! use ssnal_en::data::{generate_synthetic, SyntheticSpec};
//!
//! // a small synthetic instance (m observations × n features)
//! let prob = generate_synthetic(&SyntheticSpec {
//!     m: 30, n: 90, n0: 4, x_star: 5.0, snr: 8.0, seed: 7,
//! });
//!
//! // validate once, fit many: shape/finite checks return typed errors
//! let design = Design::new(&prob.a, &prob.b)?;
//! let mut fit = EnetModel::new()
//!     .alpha_c(0.8, 0.4)   // the paper's λ1 = α·c·λmax parametrization
//!     .tol(1e-8)
//!     .fit(&design)?;
//! assert!(fit.result().converged);
//!
//! // predict, and re-solve the same design against a new response — the
//! // warm session reuses the Newton workspace + Gram/Cholesky cache, with
//! // results bitwise-identical to a cold fit
//! let preds = fit.predict(&prob.a)?;
//! assert_eq!(preds.len(), 30);
//! let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
//! fit.refit(&b2)?;
//! # Ok::<(), ssnal_en::EnetError>(())
//! ```
//!
//! λ-paths and tuning sweeps go through the same builder
//! ([`EnetModel::fit_path`], [`EnetModel::tune`]); every algorithm is
//! reachable via [`EnetModel::algorithm`] and the [`solver::Solver`] trait
//! registry.
//!
//! ## Module map
//!
//! * [`api`] — **the estimator facade** (start here): [`Design`] /
//!   [`EnetModel`] / [`Fit`], typed [`EnetError`]s, JSON export, warm
//!   sessions,
//! * [`solver`] — the paper's contribution (SsNAL-EN) plus every baseline it
//!   is benchmarked against (coordinate descent, FISTA, ADMM, Gap-Safe
//!   screening, celer-style working sets), all behind the [`solver::Solver`]
//!   trait registry,
//! * [`prox`] — the Elastic Net proximal/conjugate toolbox (paper §2),
//! * [`path`] / [`tuning`] — warm-started λ-paths and CV/GCV/e-BIC tuning
//!   (§3.3) — the primitives the facade drives,
//! * [`parallel`] — the two-layer execution engine over one **persistent
//!   worker pool** (long-lived parked `std::thread` workers, woken per
//!   kernel call; see [`parallel::pool`]). Layer 1 parallelizes *across*
//!   the λ-grid: contiguous warm-start chains over work-stealing deques,
//!   with per-chain Gap-Safe screening and cross-chain truncation
//!   coordination. Layer 2 ([`parallel::shard`]) parallelizes *within* one
//!   solve: the `Aᵀy`/`A_J u`/Gram/CG-mat-vec/direct-Newton-triangle
//!   kernels and the Gap-Safe scoring sweeps shard their column dimension
//!   over the same pool with fixed-order tree reductions. Both layers are
//!   bitwise-deterministic: for a fixed chain split and problem shape the
//!   output is identical at every thread count and pool warmth
//!   (`SSNAL_THREADS` governs the within-solve budget),
//! * [`data`] — synthetic, LIBSVM/polynomial-expansion and SNP/GWAS pipelines
//!   (§4); [`data::snp::generate_sparse`] builds rare-variant cohorts straight
//!   into CSC with a density heuristic choosing the storage,
//! * [`runtime`] — the artifact manifest/buffer contract for the AOT-compiled
//!   JAX/Pallas graphs (execution needs an XLA/PJRT binding the offline
//!   toolchain does not ship; the engine degrades to a descriptive error),
//! * [`serve`] — the `ssnal-en serve` HTTP/1.1 front end: a fingerprint-keyed
//!   design registry, an LRU of warm [`Fit`]-equivalent sessions, batched
//!   refits with cross-request coalescing, per-request thread budgeting, a
//!   bounded FIFO admission queue with per-request deadlines (408/503 with
//!   `Retry-After`, never a wedged connection), graceful SIGTERM drain, a
//!   typed `GET /v1/stats` metrics surface ([`serve::ServeMetrics`]) and a
//!   total `EnetError` → HTTP status mapping — all over `std::net`, no
//!   dependencies. Rides on the crate's determinism contracts: server
//!   responses are byte-identical to direct [`api`] calls, and coalesced
//!   refits are byte-identical to sequential ones,
//! * [`coordinator`] — **deprecated compatibility shim** over the facade
//!   (kept so pre-facade callers compile; new code uses [`api`]),
//! * [`linalg`] / [`rng`] / [`util`] / [`bench`] — the from-scratch substrates
//!   (the offline build has no BLAS, rand, clap, serde, anyhow or criterion).
//!   [`linalg::design`] defines the dense-or-CSC-sparse storage dispatch
//!   ([`linalg::DesignRef`] / [`linalg::DesignStorage`] over
//!   [`linalg::CscMat`]) every solver entry point consumes — the sparse
//!   kernels reproduce the dense bits exactly, so storage affects wall-clock
//!   and memory, never coefficients. [`linalg::workspace`] holds the
//!   solver-wide buffer arena and the active-set-aware Gram/Cholesky cache
//!   behind the zero-allocation Newton hot path — the state a warm [`Fit`]
//!   session carries across [`Fit::refit`] calls.
//!
//! ## Continuous integration
//!
//! `.github/workflows/ci.yml` gates every push/PR on `cargo build --release`,
//! `cargo test -q` (run twice, under `SSNAL_THREADS=1` and `=4`, so the
//! sharding determinism contract is exercised on every push), `cargo fmt
//! --check`, `cargo clippy -- -D warnings` and `cargo doc --no-deps` under
//! `RUSTDOCFLAGS="-D warnings"` (broken intra-doc links in the API surface
//! fail the build), plus a bench-smoke job that runs the parallel-path,
//! shard-linalg, sparse-design, pool-dispatch, Newton-workspace, warm-path
//! and serve benchmarks on tiny synthetic problems and uploads the
//! resulting seven `BENCH_*.json` tables (the Newton section also gates
//! warm-vs-cold workspace cost and steady-state allocations; the sparse
//! section gates CSC sweeps beating their dense twins; the warm-path
//! section gates the rank-1 Cholesky edit tier beating both the
//! pivot-refactor and cold tiers with zero downdate fallbacks and zero
//! steady-state allocations; the serve section gates warm refits beating
//! cold fits through HTTP, zero queue rejections at 2× offered load, and
//! the refit-coalesce ratio exceeding 1), and a
//! bench-regression job that diffs them
//! against the committed baselines in `rust/benches/baselines/` via
//! `ssnal-en bench-check` ([`bench::check`]: structural drift and determinism
//! violations hard-fail; wall-clock regressions >25% annotate without
//! failing).

// Numeric-kernel idioms this codebase uses deliberately (index loops that
// mirror the paper's math, solver entry points with many tuning knobs).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::inherent_to_string)]

pub mod api;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod parallel;
pub mod path;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod tuning;
pub mod util;

pub use api::{Backend, Design, EnetError, EnetModel, Fit, PathFit, TuneFit};
