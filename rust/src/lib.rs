//! # SsNAL-EN — Semi-smooth Newton Augmented Lagrangian method for the Elastic Net
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *An Efficient Semi-smooth Newton Augmented Lagrangian Method for Elastic Net*
//! (Boschi, Reimherr, Chiaromonte, 2020).
//!
//! The crate is organized as:
//!
//! * [`solver`] — the paper's contribution: the SsNAL-EN solver plus every
//!   baseline it is benchmarked against (coordinate descent, FISTA, ADMM,
//!   Gap-Safe screening, celer-style working sets),
//! * [`prox`] — the Elastic Net proximal/conjugate toolbox (paper §2),
//! * [`path`] / [`tuning`] — warm-started λ-paths and CV/GCV/e-BIC tuning (§3.3),
//! * [`parallel`] — the multi-threaded λ-path/CV engine: the grid is cut into
//!   contiguous warm-start chains distributed over a `std::thread` + channel
//!   worker pool, with per-chain Gap-Safe screening and cross-chain
//!   truncation coordination. For a fixed chain split the output is
//!   bitwise-identical across thread counts; `num_threads = 1` is the
//!   single-threaded fallback,
//! * [`data`] — synthetic, LIBSVM/polynomial-expansion and SNP/GWAS pipelines (§4),
//! * [`runtime`] — the artifact manifest/buffer contract for the AOT-compiled
//!   JAX/Pallas graphs (execution needs an XLA/PJRT binding the offline
//!   toolchain does not ship; the engine degrades to a descriptive error),
//! * [`coordinator`] — the high-level API tying solver, path, tuning, data and
//!   backend selection together,
//! * [`linalg`] / [`rng`] / [`util`] / [`bench`] — the from-scratch substrates
//!   (the offline build has no BLAS, rand, clap, serde, anyhow or criterion).
//!
//! ## Continuous integration
//!
//! `.github/workflows/ci.yml` gates every push/PR on `cargo build --release`,
//! `cargo test -q`, `cargo fmt --check` and `cargo clippy -- -D warnings`,
//! plus a bench-smoke job that runs the parallel-path benchmark on a tiny
//! synthetic problem and uploads the resulting `BENCH_*.json` table.

// Numeric-kernel idioms this codebase uses deliberately (index loops that
// mirror the paper's math, solver entry points with many tuning knobs).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::inherent_to_string)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod parallel;
pub mod path;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod tuning;
pub mod util;
