//! # SsNAL-EN — Semi-smooth Newton Augmented Lagrangian method for the Elastic Net
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *An Efficient Semi-smooth Newton Augmented Lagrangian Method for Elastic Net*
//! (Boschi, Reimherr, Chiaromonte, 2020).
//!
//! The crate is organized as:
//!
//! * [`solver`] — the paper's contribution: the SsNAL-EN solver plus every
//!   baseline it is benchmarked against (coordinate descent, FISTA, ADMM,
//!   Gap-Safe screening, celer-style working sets),
//! * [`prox`] — the Elastic Net proximal/conjugate toolbox (paper §2),
//! * [`path`] / [`tuning`] — warm-started λ-paths and CV/GCV/e-BIC tuning (§3.3),
//! * [`data`] — synthetic, LIBSVM/polynomial-expansion and SNP/GWAS pipelines (§4),
//! * [`runtime`] — the PJRT engine that loads the AOT-compiled JAX/Pallas
//!   artifacts and executes them from Rust (layer boundary; Python never runs
//!   on the solve path),
//! * [`coordinator`] — the high-level API tying solver, path, tuning, data and
//!   backend selection together,
//! * [`linalg`] / [`rng`] / [`util`] / [`bench`] — the from-scratch substrates
//!   (the offline build has no BLAS, rand, clap, serde or criterion).

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod path;
pub mod prox;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod tuning;
pub mod util;
