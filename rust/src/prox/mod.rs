//! Proximal operators and Fenchel conjugates for the Lasso and Elastic Net
//! penalties — paper §2, Eq. (2), (3), (5), (6) and Figure 1.
//!
//! These closed forms are the numerical heart of SsNAL-EN:
//!
//! * [`prox_enet`] — `prox_{σp}` for `p(x) = λ1‖x‖₁ + (λ2/2)‖x‖₂²` (Eq. 6, left),
//!   i.e. scaled soft-thresholding. Its support (`|t| > σλ1`) defines the active
//!   set `J` whose cardinality `r` drives the cost of the Newton system.
//! * [`prox_enet_conj`] — `prox_{p*/σ}` (Eq. 6, right), used for the z-update.
//! * [`enet_conjugate`] — `p*(z)` (Proposition 1), a piecewise quadratic (unlike
//!   the Lasso where it is an indicator function).
//!
//! The identical formulas are implemented in `python/compile/kernels/` (Pallas L1
//! kernel + jnp oracle); `rust/tests/` and `python/tests/` cross-check them.

/// Scalar soft-thresholding operator `prox_{σλ1‖·‖₁}` (Eq. 5, left).
#[inline]
pub fn soft_threshold(t: f64, thr: f64) -> f64 {
    if t > thr {
        t - thr
    } else if t < -thr {
        t + thr
    } else {
        0.0
    }
}

/// Scalar `prox_{σp}` for the Elastic Net penalty (Eq. 6, left):
/// `prox(t) = soft(t, σλ1) / (1 + σλ2)`.
#[inline]
pub fn prox_enet_scalar(t: f64, sigma: f64, lam1: f64, lam2: f64) -> f64 {
    soft_threshold(t, sigma * lam1) / (1.0 + sigma * lam2)
}

/// Scalar `prox_{p*/σ}` for the Elastic Net (Eq. 6, right). The argument is
/// `t/σ` in the paper's notation — here we take the *pre-division* value `t`
/// together with σ so the three branches match Eq. (6) literally.
#[inline]
pub fn prox_enet_conj_scalar(t: f64, sigma: f64, lam1: f64, lam2: f64) -> f64 {
    let thr = sigma * lam1;
    if t >= thr {
        (t * lam2 + lam1) / (1.0 + sigma * lam2)
    } else if t <= -thr {
        (t * lam2 - lam1) / (1.0 + sigma * lam2)
    } else {
        t / sigma
    }
}

/// Vector `prox_{σp}(t)` writing into `out`; returns the number of active
/// (nonzero) coordinates `r = |J|`.
pub fn prox_enet(t: &[f64], sigma: f64, lam1: f64, lam2: f64, out: &mut [f64]) -> usize {
    assert_eq!(t.len(), out.len());
    let thr = sigma * lam1;
    let scale = 1.0 / (1.0 + sigma * lam2);
    let mut r = 0;
    for i in 0..t.len() {
        let ti = t[i];
        out[i] = if ti > thr {
            r += 1;
            (ti - thr) * scale
        } else if ti < -thr {
            r += 1;
            (ti + thr) * scale
        } else {
            0.0
        };
    }
    r
}

/// Fused `prox_{σp}` + active-set extraction: writes the prox into `out` and the
/// active indices into `active` (cleared first). This is the Rust twin of the
/// L1 Pallas kernel's fused prox/mask stage.
pub fn prox_enet_with_support(
    t: &[f64],
    sigma: f64,
    lam1: f64,
    lam2: f64,
    out: &mut [f64],
    active: &mut Vec<usize>,
) {
    assert_eq!(t.len(), out.len());
    active.clear();
    let thr = sigma * lam1;
    let scale = 1.0 / (1.0 + sigma * lam2);
    for i in 0..t.len() {
        let ti = t[i];
        if ti > thr {
            out[i] = (ti - thr) * scale;
            active.push(i);
        } else if ti < -thr {
            out[i] = (ti + thr) * scale;
            active.push(i);
        } else {
            out[i] = 0.0;
        }
    }
}

/// Vector `prox_{p*/σ}(t/σ)` (Eq. 6 right), into `out`.
pub fn prox_enet_conj(t: &[f64], sigma: f64, lam1: f64, lam2: f64, out: &mut [f64]) {
    assert_eq!(t.len(), out.len());
    for i in 0..t.len() {
        out[i] = prox_enet_conj_scalar(t[i], sigma, lam1, lam2);
    }
}

/// Elastic Net penalty value `p(x) = λ1‖x‖₁ + (λ2/2)‖x‖₂²`.
pub fn enet_penalty(x: &[f64], lam1: f64, lam2: f64) -> f64 {
    let mut l1 = 0.0;
    let mut l2 = 0.0;
    for &v in x {
        l1 += v.abs();
        l2 += v * v;
    }
    lam1 * l1 + 0.5 * lam2 * l2
}

/// Fenchel conjugate of the Elastic Net penalty, `p*(z)` (Proposition 1, Eq. 3).
/// Requires `λ2 > 0`; for `λ2 = 0` use [`lasso_conjugate`].
pub fn enet_conjugate(z: &[f64], lam1: f64, lam2: f64) -> f64 {
    assert!(lam2 > 0.0, "enet conjugate needs λ2 > 0");
    let mut s = 0.0;
    for &zi in z {
        if zi >= lam1 {
            let d = zi - lam1;
            s += d * d;
        } else if zi <= -lam1 {
            let d = zi + lam1;
            s += d * d;
        }
    }
    s / (2.0 * lam2)
}

/// Fenchel conjugate of the Lasso penalty (Eq. 2): the indicator of
/// `‖z‖∞ ≤ λ1` — returns `f64::INFINITY` outside (with a small tolerance).
pub fn lasso_conjugate(z: &[f64], lam1: f64) -> f64 {
    let tol = 1e-12 * (1.0 + lam1);
    for &zi in z {
        if zi.abs() > lam1 + tol {
            return f64::INFINITY;
        }
    }
    0.0
}

/// Conjugate of the quadratic loss `h(u) = ½‖u − b‖²`:
/// `h*(y) = ½‖y‖² + bᵀy` (paper §3).
pub fn h_star(y: &[f64], b: &[f64]) -> f64 {
    assert_eq!(y.len(), b.len());
    let mut s = 0.0;
    for i in 0..y.len() {
        s += 0.5 * y[i] * y[i] + b[i] * y[i];
    }
    s
}

/// Clarke-subdifferential diagonal entry of `prox_{σp}` at `t` (Eq. 17):
/// `1/(1+σλ2)` if `|t| > σλ1` else `0`.
#[inline]
pub fn prox_enet_jacobian_diag(t: f64, sigma: f64, lam1: f64, lam2: f64) -> f64 {
    if t.abs() > sigma * lam1 {
        1.0 / (1.0 + sigma * lam2)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L1: f64 = 1.0;
    const L2: f64 = 1.0;
    const SIG: f64 = 1.0;

    #[test]
    fn soft_threshold_branches() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-1.0, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn prox_enet_matches_eq6() {
        // Eq. 6 with σ=λ1=λ2=1: prox(t) = (t∓1)/2 outside [−1,1], 0 inside.
        assert_eq!(prox_enet_scalar(3.0, SIG, L1, L2), 1.0);
        assert_eq!(prox_enet_scalar(-3.0, SIG, L1, L2), -1.0);
        assert_eq!(prox_enet_scalar(0.3, SIG, L1, L2), 0.0);
    }

    #[test]
    fn prox_reduces_to_soft_threshold_when_lam2_zero() {
        for t in [-2.5, -1.0, 0.0, 0.7, 4.0] {
            assert_eq!(prox_enet_scalar(t, 2.0, 0.5, 0.0), soft_threshold(t, 1.0));
        }
    }

    #[test]
    fn prox_defining_minimization_holds() {
        // prox_{σp}(t) must minimize  p(u) + (1/(2σ))(u−t)²  — grid check.
        let (sigma, lam1, lam2) = (0.7, 0.9, 1.3);
        for &t in &[-3.0, -1.0, -0.5, 0.0, 0.63, 1.0, 2.5] {
            let star = prox_enet_scalar(t, sigma, lam1, lam2);
            let obj = |u: f64| {
                lam1 * u.abs() + 0.5 * lam2 * u * u + (u - t) * (u - t) / (2.0 * sigma)
            };
            let fstar = obj(star);
            let mut u = -4.0;
            while u <= 4.0 {
                assert!(fstar <= obj(u) + 1e-9, "t={t}, u={u}");
                u += 0.01;
            }
        }
    }

    #[test]
    fn moreau_decomposition_identity() {
        // x = prox_{σp}(x) + σ·prox_{p*/σ}(x/σ)  (paper §2.2).
        let (sigma, lam1, lam2) = (0.8, 1.2, 0.6);
        for &x in &[-5.0, -1.0, -0.3, 0.0, 0.3, 0.96, 2.0, 7.5] {
            let a = prox_enet_scalar(x, sigma, lam1, lam2);
            let bpart = prox_enet_conj_scalar(x, sigma, lam1, lam2);
            assert!((x - (a + sigma * bpart)).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn conjugate_matches_proposition1() {
        // σ=1, λ1=λ2=1: p*(2) = (2−1)²/2 = 0.5; p*(0.5)=0; p*(−3)=(−3+1)²/2=2.
        assert!((enet_conjugate(&[2.0], L1, L2) - 0.5).abs() < 1e-15);
        assert_eq!(enet_conjugate(&[0.5], L1, L2), 0.0);
        assert!((enet_conjugate(&[-3.0], L1, L2) - 2.0).abs() < 1e-15);
        // additivity over coordinates
        let all = enet_conjugate(&[2.0, 0.5, -3.0], L1, L2);
        assert!((all - 2.5).abs() < 1e-15);
    }

    #[test]
    fn conjugate_fenchel_young_inequality() {
        // p(x) + p*(z) ≥ x·z for all x, z (scalar grid).
        let (lam1, lam2) = (1.1, 0.7);
        let mut x = -3.0;
        while x <= 3.0 {
            let mut z = -3.0;
            while z <= 3.0 {
                let lhs = enet_penalty(&[x], lam1, lam2) + enet_conjugate(&[z], lam1, lam2);
                assert!(lhs >= x * z - 1e-10, "x={x} z={z}");
                z += 0.17;
            }
            x += 0.17;
        }
    }

    #[test]
    fn conjugate_is_sup_attained() {
        // p*(z) = sup_x (zx − p(x)); dense grid should come within 1e-4.
        let (lam1, lam2) = (1.0, 2.0);
        for &z in &[-4.0, -1.5, 0.0, 0.5, 1.0, 2.7] {
            let closed = enet_conjugate(&[z], lam1, lam2);
            let mut best = f64::NEG_INFINITY;
            let mut x = -10.0;
            while x <= 10.0 {
                best = best.max(z * x - enet_penalty(&[x], lam1, lam2));
                x += 1e-3;
            }
            assert!((closed - best).abs() < 1e-4, "z={z}: {closed} vs {best}");
        }
    }

    #[test]
    fn lasso_conjugate_indicator() {
        assert_eq!(lasso_conjugate(&[0.5, -1.0], 1.0), 0.0);
        assert_eq!(lasso_conjugate(&[1.5], 1.0), f64::INFINITY);
    }

    #[test]
    fn vector_prox_counts_active() {
        let t = [3.0, 0.2, -2.0, 0.9, -0.5];
        let mut out = [0.0; 5];
        let r = prox_enet(&t, SIG, L1, L2, &mut out);
        assert_eq!(r, 2);
        assert_eq!(out, [1.0, 0.0, -0.5, 0.0, 0.0]);
    }

    #[test]
    fn with_support_matches_plain() {
        let t = [3.0, 0.2, -2.0, 0.9, -0.5, 1.0001];
        let mut out1 = [0.0; 6];
        let mut out2 = [0.0; 6];
        let mut active = Vec::new();
        let r = prox_enet(&t, SIG, L1, L2, &mut out1);
        prox_enet_with_support(&t, SIG, L1, L2, &mut out2, &mut active);
        assert_eq!(out1, out2);
        assert_eq!(active.len(), r);
        assert_eq!(active, vec![0, 2, 5]);
    }

    #[test]
    fn jacobian_diag_matches_eq17() {
        assert_eq!(prox_enet_jacobian_diag(2.0, SIG, L1, L2), 0.5);
        assert_eq!(prox_enet_jacobian_diag(0.5, SIG, L1, L2), 0.0);
        assert_eq!(prox_enet_jacobian_diag(-2.0, 1.0, 1.0, 0.0), 1.0);
    }

    #[test]
    fn h_star_value() {
        // h*(y) = ½‖y‖² + bᵀy
        let y = [1.0, -2.0];
        let b = [3.0, 1.0];
        assert_eq!(h_star(&y, &b), 0.5 * 5.0 + (3.0 - 2.0));
    }

    #[test]
    fn prox_conj_is_derivative_scaled_fixed_point() {
        // By B.3: u = prox_{p*/σ}(t/σ)  iff  t/σ − u ∈ ∂(p*/σ)(u) = ∇p*(u)/σ.
        // With p* differentiable: σ(t/σ − u) = ∇p*(u), ∇p*(u) = (u∓λ1)/λ2·… —
        // easier: check it agrees with Moreau + prox (already covered) at kinks.
        let (sigma, lam1, lam2) = (1.5, 1.0, 2.0);
        let at_kink = prox_enet_conj_scalar(sigma * lam1, sigma, lam1, lam2);
        let below = prox_enet_conj_scalar(sigma * lam1 - 1e-9, sigma, lam1, lam2);
        assert!((at_kink - below).abs() < 1e-8, "continuity at +kink");
        let at_kink_n = prox_enet_conj_scalar(-sigma * lam1, sigma, lam1, lam2);
        let above = prox_enet_conj_scalar(-sigma * lam1 + 1e-9, sigma, lam1, lam2);
        assert!((at_kink_n - above).abs() < 1e-8, "continuity at −kink");
    }
}
