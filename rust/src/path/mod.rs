//! Warm-started λ-path driver (paper §3.3).
//!
//! "We start from values of λ1 very close to ‖Aᵀb‖∞ … when we move to the next
//! value of λ1, we use the solution at the previous value for initialization
//! (warm-start) … we allow the user to fix the maximum number of active
//! features: when this number is reached, no further λ values are explored."
//!
//! This module owns the *sequential* chain primitive ([`WarmState`] +
//! [`solve_point`]) and the single-chain driver [`solve_path`]. The
//! multi-threaded engine in [`crate::parallel`] reuses the exact same
//! primitive, so a path executed as one chain is bitwise-identical no matter
//! which driver ran it. Downstream callers reach paths through the facade —
//! [`crate::api::EnetModel::fit_path`] (with
//! [`crate::api::EnetModel::sequential`] reproducing this driver's bits) —
//! which validates inputs into typed errors before handing them here.
//! "Sequential" here means grid-sequential: each solve still shards its
//! O(mn) sweeps over [`crate::parallel::shard`]'s ambient thread budget
//! (`SSNAL_THREADS`), whose results are thread-count-invariant — so the
//! bitwise guarantee survives within-solve parallelism too.

use crate::linalg::DesignRef;
use crate::solver::types::{Algorithm, BaselineOptions, EnetProblem, SolveResult, SsnalOptions};
use crate::solver::{cd, ssnal};

/// Log-spaced grid of `c_λ` values from `hi` down to `lo` (paper D.4 uses 100
/// log-spaced points between 1 and 0.1).
pub fn c_lambda_grid(hi: f64, lo: f64, count: usize) -> Vec<f64> {
    assert!(hi > lo && lo > 0.0 && count >= 2);
    let (lh, ll) = (hi.ln(), lo.ln());
    (0..count)
        .map(|k| (lh + (ll - lh) * k as f64 / (count - 1) as f64).exp())
        .collect()
}

/// Options for a path run.
#[derive(Clone, Debug)]
pub struct PathOptions {
    /// Mixing parameter α (λ1 = α·c·λmax, λ2 = (1−α)·c·λmax).
    pub alpha: f64,
    /// Descending c_λ grid.
    pub c_grid: Vec<f64>,
    /// Stop exploring once this many features are active (0 = no cap).
    pub max_active: usize,
    /// Solver tolerance.
    pub tol: f64,
    /// Which solver drives the path (SsnalEn, CdNaive or CdCovariance).
    pub algorithm: Algorithm,
}

impl Default for PathOptions {
    fn default() -> Self {
        Self {
            alpha: 0.8,
            c_grid: c_lambda_grid(1.0, 0.1, 100),
            max_active: 100,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        }
    }
}

/// One solved point on the path.
#[derive(Clone, Debug)]
pub struct PathPoint {
    pub c_lambda: f64,
    pub lam1: f64,
    pub lam2: f64,
    pub result: SolveResult,
}

/// A complete path run.
#[derive(Clone, Debug)]
pub struct PathResult {
    pub points: Vec<PathPoint>,
    /// λ^max = ‖Aᵀb‖∞/α used for the parametrization.
    pub lambda_max: f64,
    /// Number of grid values actually explored ("runs" column of Table D.4).
    pub runs: usize,
    /// Whether the max-active cap triggered early stop.
    pub truncated: bool,
}

/// Warm state carried along one warm-start chain: the previous solution, the
/// carried AL penalty σ, and the Newton workspace. Near the previous
/// solution the AL multiplier is already accurate, so restarting at
/// σ0 = 5e-3 would waste outer iterations re-growing σ (paper: warm-started
/// points converge in ~1 iteration).
#[derive(Clone, Debug, Default)]
pub struct WarmState {
    /// Previous primal solution (length n), if any.
    pub x: Option<Vec<f64>>,
    /// σ carried from the previous SsNAL solve.
    pub sigma: Option<f64>,
    /// Newton buffers + active-set-aware factorization cache, reused across
    /// the chain's warm-started λ-steps: nearby λ values keep (most of) the
    /// active set, so consecutive solves reuse the Woodbury Gram — and often
    /// the whole Cholesky — instead of rebuilding per point. Cached entries
    /// key on column indices of the bound design (the workspace self-resets
    /// on a different one), and cache hits are bitwise-identical to cold
    /// rebuilds, so the path's bits are unchanged.
    pub newton_ws: crate::linalg::NewtonWorkspace,
    /// When the workspace is currently bound to a *gathered sub-design*
    /// (screened chain steps), the full-design column index of each
    /// sub-design column; `None` = bound to the full design. The screened
    /// driver uses this to retarget the warm workspace between survivor
    /// coordinate systems ([`crate::linalg::NewtonWorkspace::retarget_columns`])
    /// instead of resetting it per λ point.
    pub ws_cols: Option<Vec<usize>>,
}

/// Validate a descending c_λ grid (shared by the sequential and parallel
/// drivers).
pub fn assert_descending_grid(grid: &[f64]) {
    assert!(!grid.is_empty());
    for w in grid.windows(2) {
        assert!(w[0] > w[1], "c_grid must be strictly descending");
    }
}

/// Solve a single grid point at `c`, reading and updating the chain's warm
/// state. This is the one primitive both [`solve_path`] and the parallel
/// engine's chains execute, which keeps their per-point numerics identical.
pub fn solve_point<'a>(
    a: impl Into<DesignRef<'a>>,
    b: &[f64],
    lambda_max: f64,
    c: f64,
    opts: &PathOptions,
    warm: &mut WarmState,
) -> PathPoint {
    let a = a.into();
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(opts.alpha, c, lambda_max);
    let p = EnetProblem::new(a, b, lam1, lam2);
    let result = match opts.algorithm {
        Algorithm::SsnalEn => {
            let defaults = SsnalOptions::default();
            // σ carry capped to keep the subproblem well conditioned.
            let sigma0 = warm.sigma.unwrap_or(defaults.sigma0).min(1e4);
            let sopts = SsnalOptions { tol: opts.tol, sigma0, ..defaults };
            let (res, trace) =
                ssnal::solve_warm_ws(&p, &sopts, warm.x.as_deref(), &mut warm.newton_ws);
            warm.sigma = Some(trace.final_sigma);
            res
        }
        Algorithm::CdNaive => cd::solve_naive_warm(
            &p,
            &BaselineOptions { tol: opts.tol, ..Default::default() },
            warm.x.as_deref(),
        ),
        Algorithm::CdCovariance => cd::solve_covariance_warm(
            &p,
            &BaselineOptions { tol: opts.tol, ..Default::default() },
            warm.x.as_deref(),
        ),
        other => panic!("path driver supports ssnal/cd algorithms, not {other:?}"),
    };
    warm.x = Some(result.x.clone());
    PathPoint { c_lambda: c, lam1, lam2, result }
}

/// Run the warm-started path as a single sequential chain.
pub fn solve_path<'a>(a: impl Into<DesignRef<'a>>, b: &[f64], opts: &PathOptions) -> PathResult {
    let a = a.into();
    assert_descending_grid(&opts.c_grid);
    let lambda_max = EnetProblem::lambda_max(a, b, opts.alpha);
    let mut points = Vec::with_capacity(opts.c_grid.len());
    let mut warm = WarmState::default();
    let mut truncated = false;

    for &c in &opts.c_grid {
        let pt = solve_point(a, b, lambda_max, c, opts, &mut warm);
        let r = pt.result.active_set.len();
        points.push(pt);
        if opts.max_active > 0 && r >= opts.max_active {
            truncated = true;
            break;
        }
    }
    let runs = points.len();
    PathResult { points, lambda_max, runs, truncated }
}

/// Find the largest `c_λ` in a descending grid whose solution has exactly (or
/// first reaches ≥) `target_active` active features — how the paper selects
/// the c_λ column of Tables 1 and 2. Returns the matching path point index.
pub fn first_reaching_active(path: &PathResult, target_active: usize) -> Option<usize> {
    path.points.iter().position(|pt| pt.result.active_set.len() >= target_active)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_synthetic, SyntheticSpec};

    fn small_problem() -> crate::data::SyntheticProblem {
        generate_synthetic(&SyntheticSpec {
            m: 50,
            n: 200,
            n0: 10,
            x_star: 5.0,
            snr: 10.0,
            seed: 42,
        })
    }

    #[test]
    fn grid_is_log_spaced_descending() {
        let g = c_lambda_grid(1.0, 0.1, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[4] - 0.1).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
        // log-spacing: ratios constant
        let r0 = g[1] / g[0];
        let r1 = g[3] / g[2];
        assert!((r0 - r1).abs() < 1e-12);
    }

    #[test]
    fn active_set_grows_along_path() {
        let prob = small_problem();
        let opts = PathOptions {
            alpha: 0.8,
            c_grid: c_lambda_grid(0.95, 0.1, 12),
            max_active: 0,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        };
        let path = solve_path(&prob.a, &prob.b, &opts);
        assert_eq!(path.runs, 12);
        let sizes: Vec<usize> = path.points.iter().map(|p| p.result.active_set.len()).collect();
        // allow small non-monotonicity but overall growth
        assert!(sizes.last().unwrap() > sizes.first().unwrap());
        assert!(*sizes.last().unwrap() >= 10, "end of path should catch the truth");
    }

    #[test]
    fn max_active_truncates() {
        let prob = small_problem();
        let opts = PathOptions {
            alpha: 0.8,
            c_grid: c_lambda_grid(0.95, 0.05, 50),
            max_active: 10,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        };
        let path = solve_path(&prob.a, &prob.b, &opts);
        assert!(path.truncated);
        assert!(path.runs < 50);
        assert!(path.points.last().unwrap().result.active_set.len() >= 10);
    }

    #[test]
    fn ssnal_and_cd_paths_agree() {
        let prob = small_problem();
        let grid = c_lambda_grid(0.9, 0.3, 6);
        let mk = |algorithm| PathOptions {
            alpha: 0.7,
            c_grid: grid.clone(),
            max_active: 0,
            tol: 1e-8,
            algorithm,
        };
        let ps = solve_path(&prob.a, &prob.b, &mk(Algorithm::SsnalEn));
        let pc = solve_path(&prob.a, &prob.b, &mk(Algorithm::CdCovariance));
        for (a, b) in ps.points.iter().zip(pc.points.iter()) {
            let dist = crate::linalg::blas::dist2(&a.result.x, &b.result.x);
            assert!(dist < 1e-3, "c={}: dist {dist}", a.c_lambda);
        }
    }

    #[test]
    fn warm_start_means_few_iterations_late_in_path() {
        let prob = small_problem();
        let opts = PathOptions {
            alpha: 0.8,
            c_grid: c_lambda_grid(0.95, 0.2, 30),
            max_active: 0,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        };
        let path = solve_path(&prob.a, &prob.b, &opts);
        // paper: "usually SsNAL-EN converges in just one iteration" on warm starts
        let late = &path.points[10..];
        let avg: f64 = late.iter().map(|p| p.result.iterations as f64).sum::<f64>()
            / late.len() as f64;
        assert!(avg <= 2.5, "avg late-path iterations {avg} (paper: ≈1 with warm starts)");
    }

    #[test]
    fn first_reaching_active_finds_target() {
        let prob = small_problem();
        let opts = PathOptions {
            alpha: 0.8,
            c_grid: c_lambda_grid(0.95, 0.05, 40),
            max_active: 0,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        };
        let path = solve_path(&prob.a, &prob.b, &opts);
        let idx = first_reaching_active(&path, 5).expect("should reach 5 active");
        assert!(path.points[idx].result.active_set.len() >= 5);
        if idx > 0 {
            assert!(path.points[idx - 1].result.active_set.len() < 5);
        }
    }
}
