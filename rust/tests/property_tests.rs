//! Property-based tests on solver and prox invariants, using the in-repo
//! quickcheck substrate (`util::quickcheck`) over randomized problems.

use ssnal_en::linalg::{blas, Mat};
use ssnal_en::prox;
use ssnal_en::rng::Xoshiro256pp;
use ssnal_en::solver::types::{EnetProblem, SsnalOptions};
use ssnal_en::solver::{primal_objective, ssnal};
use ssnal_en::util::quickcheck::{log_uniform_usize, run_prop, PropConfig};

/// A random Elastic Net instance for property checks.
#[derive(Debug)]
struct RandomInstance {
    a: Mat,
    b: Vec<f64>,
    lam1: f64,
    lam2: f64,
}

fn gen_instance(rng: &mut Xoshiro256pp) -> RandomInstance {
    let m = log_uniform_usize(rng, 10, 60);
    let n = log_uniform_usize(rng, 20, 300);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let b: Vec<f64> = (0..m).map(|_| 3.0 * rng.next_gaussian()).collect();
    let lmax = EnetProblem::lambda_max(&a, &b, 1.0).max(1e-6);
    let lam1 = lmax * (0.05 + 0.9 * rng.next_f64());
    let lam2 = lmax * rng.next_f64();
    RandomInstance { a, b, lam1, lam2 }
}

#[test]
fn prop_solution_is_a_minimizer() {
    // obj(x̂) ≤ obj(x̂ + δ) for random perturbations δ.
    run_prop(
        PropConfig { cases: 25, seed: 0xA1 },
        gen_instance,
        |inst| {
            let p = EnetProblem::new(&inst.a, &inst.b, inst.lam1, inst.lam2);
            let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
            if !res.converged {
                return Err("did not converge".into());
            }
            let f0 = primal_objective(&p, &res.x);
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            for scale in [1e-3, 1e-2, 0.1] {
                let mut xp = res.x.clone();
                for v in xp.iter_mut() {
                    *v += scale * rng.next_gaussian();
                }
                let fp = primal_objective(&p, &xp);
                if fp < f0 - 1e-7 * (1.0 + f0.abs()) {
                    return Err(format!("perturbation improved objective: {fp} < {f0}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_zero_above_lambda_max() {
    run_prop(
        PropConfig { cases: 30, seed: 0xB2 },
        |rng| {
            let m = log_uniform_usize(rng, 5, 40);
            let n = log_uniform_usize(rng, 10, 200);
            let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
            let b: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
            (a, b)
        },
        |(a, b)| {
            let lmax = EnetProblem::lambda_max(a, b, 1.0);
            let p = EnetProblem::new(a, b, lmax * 1.0001, 0.5);
            let res = ssnal::solve(&p, &SsnalOptions::default());
            if res.x.iter().any(|&v| v != 0.0) {
                return Err("nonzero solution above λmax".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scaling_invariance() {
    // scaling (b, λ1) by t scales the Lasso solution path point by t when
    // λ2 also scales by t — homogeneity of the optimality conditions.
    run_prop(
        PropConfig { cases: 15, seed: 0xC3 },
        gen_instance,
        |inst| {
            let t = 3.0;
            let p1 = EnetProblem::new(&inst.a, &inst.b, inst.lam1, inst.lam2);
            let bt: Vec<f64> = inst.b.iter().map(|v| v * t).collect();
            let p2 = EnetProblem::new(&inst.a, &bt, inst.lam1 * t, inst.lam2);
            let opts = SsnalOptions { tol: 1e-10, ..Default::default() };
            let r1 = ssnal::solve(&p1, &opts);
            let r2 = ssnal::solve(&p2, &opts);
            if !(r1.converged && r2.converged) {
                return Err("no convergence".into());
            }
            let scaled: Vec<f64> = r1.x.iter().map(|v| v * t).collect();
            let dist = blas::dist2(&scaled, &r2.x);
            let scale = blas::nrm2(&scaled) + 1.0;
            if dist / scale > 1e-5 {
                return Err(format!("homogeneity violated: {dist}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prox_nonexpansive() {
    // proximal operators are 1-Lipschitz: |prox(a) − prox(b)| ≤ |a − b|.
    run_prop(
        PropConfig { cases: 200, seed: 0xD4 },
        |rng| {
            let a = 10.0 * (rng.next_f64() - 0.5);
            let b = 10.0 * (rng.next_f64() - 0.5);
            let sigma = 0.01 + 2.0 * rng.next_f64();
            let lam1 = 2.0 * rng.next_f64();
            let lam2 = 2.0 * rng.next_f64();
            (a, b, sigma, lam1, lam2)
        },
        |&(a, b, sigma, lam1, lam2)| {
            let pa = prox::prox_enet_scalar(a, sigma, lam1, lam2);
            let pb = prox::prox_enet_scalar(b, sigma, lam1, lam2);
            if (pa - pb).abs() > (a - b).abs() + 1e-12 {
                return Err(format!("prox expansive: |{pa}−{pb}| > |{a}−{b}|"));
            }
            // conjugate prox too (firmly nonexpansive in the Moreau pair)
            let ca = prox::prox_enet_conj_scalar(a, sigma, lam1, lam2);
            let cb = prox::prox_enet_conj_scalar(b, sigma, lam1, lam2);
            if sigma * (ca - cb).abs() > (a - b).abs() + 1e-12 {
                return Err("conjugate prox expansive".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_moreau_vector_identity() {
    run_prop(
        PropConfig { cases: 60, seed: 0xE5 },
        |rng| {
            let n = log_uniform_usize(rng, 1, 100);
            let t: Vec<f64> = (0..n).map(|_| 8.0 * (rng.next_f64() - 0.5)).collect();
            let sigma = 0.05 + 2.0 * rng.next_f64();
            let lam1 = 2.0 * rng.next_f64();
            let lam2 = 0.01 + 2.0 * rng.next_f64();
            (t, sigma, lam1, lam2)
        },
        |(t, sigma, lam1, lam2)| {
            let n = t.len();
            let mut u = vec![0.0; n];
            let mut z = vec![0.0; n];
            prox::prox_enet(t, *sigma, *lam1, *lam2, &mut u);
            prox::prox_enet_conj(t, *sigma, *lam1, *lam2, &mut z);
            for i in 0..n {
                let recon = u[i] + sigma * z[i];
                if (recon - t[i]).abs() > 1e-10 * (1.0 + t[i].abs()) {
                    return Err(format!("Moreau identity broken at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_duality_gap_nonnegative() {
    // For any feasible-ish dual pair built from an arbitrary x, gap ≥ 0.
    run_prop(
        PropConfig { cases: 40, seed: 0xF6 },
        gen_instance,
        |inst| {
            if inst.lam2 == 0.0 {
                return Ok(()); // handled by the scaled-point construction elsewhere
            }
            let p = EnetProblem::new(&inst.a, &inst.b, inst.lam1, inst.lam2);
            let mut rng = Xoshiro256pp::seed_from_u64(3);
            let x: Vec<f64> = (0..p.n()).map(|_| 0.5 * rng.next_gaussian()).collect();
            let ax = p.a.mul_vec(&x);
            let y: Vec<f64> = (0..p.m()).map(|i| ax[i] - p.b[i]).collect();
            let z: Vec<f64> = p.a.t_mul_vec(&y).iter().map(|v| -v).collect();
            let gap = ssnal_en::solver::duality_gap(&p, &x, &y, &z);
            if gap < -1e-9 {
                return Err(format!("negative duality gap {gap}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_warm_start_never_slower_by_much() {
    run_prop(
        PropConfig { cases: 10, seed: 0x1234 },
        gen_instance,
        |inst| {
            let p = EnetProblem::new(&inst.a, &inst.b, inst.lam1, inst.lam2);
            let opts = SsnalOptions::default();
            let cold = ssnal::solve(&p, &opts);
            if !cold.converged {
                return Err("cold no convergence".into());
            }
            let (warm, _) = ssnal::solve_warm(&p, &opts, Some(&cold.x));
            if warm.iterations > cold.iterations + 1 {
                return Err(format!(
                    "warm start slower: {} vs {}",
                    warm.iterations, cold.iterations
                ));
            }
            Ok(())
        },
    );
}
