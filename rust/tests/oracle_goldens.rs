//! Cross-solver oracle suite (ISSUE 2): committed golden fixtures checked
//! against SSNAL, coordinate descent, and FISTA to a shared tolerance, so a
//! solver refactor cannot silently drift all solvers together.
//!
//! The goldens in `fixtures/oracle_goldens.json` are **analytic**, not
//! recorded solver output: each case has a closed-form Elastic Net solution
//! (orthogonal/diagonal designs → separable soft-thresholding; pure ridge →
//! normal equations; λ1 ≥ λmax → exact zero), worked out in exact rational
//! arithmetic. If every solver in the crate acquired the same bug, these
//! tests would still catch it.

use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{blas, Mat};
use ssnal_en::solver::objective::{kkt_residuals, primal_objective};
use ssnal_en::solver::types::{BaselineOptions, EnetProblem, SsnalOptions};
use ssnal_en::solver::{cd, fista, ssnal};
use ssnal_en::util::json::Json;

struct GoldenCase {
    name: String,
    a: Mat,
    b: Vec<f64>,
    lam1: f64,
    lam2: f64,
    expected_x: Vec<f64>,
    expected_objective: f64,
    tol_x: f64,
    tol_objective: f64,
    kkt_tol: f64,
}

fn f64_field(j: &Json, key: &str) -> f64 {
    j.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("fixture field {key} missing or not a number"))
}

fn vec_field(j: &Json, key: &str) -> Vec<f64> {
    j.get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("fixture field {key} missing or not an array"))
        .iter()
        .map(|v| v.as_f64().expect("numeric array element"))
        .collect()
}

fn load_cases() -> Vec<GoldenCase> {
    let text = include_str!("fixtures/oracle_goldens.json");
    let doc = Json::parse(text).expect("oracle_goldens.json parses");
    let cases = doc.get("cases").and_then(Json::as_arr).expect("cases array");
    assert!(cases.len() >= 5, "fixture should carry several goldens");
    cases
        .iter()
        .map(|c| {
            let m = f64_field(c, "m") as usize;
            let n = f64_field(c, "n") as usize;
            let a_rm = vec_field(c, "a_row_major");
            GoldenCase {
                name: c
                    .get("name")
                    .and_then(Json::as_str)
                    .expect("case name")
                    .to_string(),
                a: Mat::from_row_major(m, n, &a_rm),
                b: vec_field(c, "b"),
                lam1: f64_field(c, "lam1"),
                lam2: f64_field(c, "lam2"),
                expected_x: vec_field(c, "expected_x"),
                expected_objective: f64_field(c, "expected_objective"),
                tol_x: f64_field(c, "tol_x"),
                tol_objective: f64_field(c, "tol_objective"),
                kkt_tol: f64_field(c, "kkt_tol"),
            }
        })
        .collect()
}

/// Check one solver's output against a golden case.
fn check_against_golden(case: &GoldenCase, solver: &str, x: &[f64], converged: bool) {
    let name = &case.name;
    assert!(converged, "{solver} did not converge on {name}");
    assert_eq!(x.len(), case.expected_x.len());
    for (j, (&got, &want)) in x.iter().zip(case.expected_x.iter()).enumerate() {
        assert!(
            (got - want).abs() <= case.tol_x * (1.0 + want.abs()),
            "{solver} on {name}: x[{j}] = {got} vs golden {want}"
        );
    }
    let p = EnetProblem::new(&case.a, &case.b, case.lam1, case.lam2);
    let obj = primal_objective(&p, x);
    assert!(
        (obj - case.expected_objective).abs()
            <= case.tol_objective * (1.0 + case.expected_objective.abs()),
        "{solver} on {name}: objective {obj} vs golden {}",
        case.expected_objective
    );
    // the golden is the true minimum: no solver may report a lower objective
    assert!(
        obj >= case.expected_objective - 1e-9 * (1.0 + case.expected_objective.abs()),
        "{solver} on {name}: objective {obj} below the analytic optimum {}",
        case.expected_objective
    );
    // KKT at the natural dual pair y = Ax − b, z = −Aᵀy (res2 is the
    // informative one for λ2 > 0; res1/res3 vanish by construction)
    let ax = case.a.mul_vec(x);
    let y: Vec<f64> = (0..p.m()).map(|i| ax[i] - case.b[i]).collect();
    let z: Vec<f64> = case.a.t_mul_vec(&y).iter().map(|v| -v).collect();
    let kkt = kkt_residuals(&p, x, &y, &z);
    assert!(
        kkt.max() <= case.kkt_tol,
        "{solver} on {name}: KKT residual {:?} above {}",
        kkt,
        case.kkt_tol
    );
}

#[test]
fn ssnal_matches_analytic_goldens() {
    for case in load_cases() {
        let p = EnetProblem::new(&case.a, &case.b, case.lam1, case.lam2);
        let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
        check_against_golden(&case, "ssnal", &res.x, res.converged);
    }
}

#[test]
fn cd_naive_matches_analytic_goldens() {
    for case in load_cases() {
        let p = EnetProblem::new(&case.a, &case.b, case.lam1, case.lam2);
        let res = cd::solve_naive(&p, &BaselineOptions { tol: 1e-12, ..Default::default() });
        check_against_golden(&case, "cd-naive", &res.x, res.converged);
    }
}

#[test]
fn cd_covariance_matches_analytic_goldens() {
    for case in load_cases() {
        let p = EnetProblem::new(&case.a, &case.b, case.lam1, case.lam2);
        let res = cd::solve_covariance(&p, &BaselineOptions { tol: 1e-12, ..Default::default() });
        check_against_golden(&case, "cd-cov", &res.x, res.converged);
    }
}

#[test]
fn fista_matches_analytic_goldens() {
    for case in load_cases() {
        let p = EnetProblem::new(&case.a, &case.b, case.lam1, case.lam2);
        let opts = BaselineOptions { tol: 1e-10, max_iters: 2_000_000, ..Default::default() };
        let res = fista::solve_fista(&p, &opts, true);
        check_against_golden(&case, "fista", &res.x, res.converged);
    }
}

/// Cross-solver consistency on a committed synthetic spec: all solvers must
/// land on the same solution within a shared tolerance. Analytic goldens pin
/// absolute truth on separable designs; this pins mutual agreement on a
/// correlated one.
#[test]
fn solvers_agree_on_committed_synthetic_instance() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 50,
        n: 150,
        n0: 6,
        x_star: 5.0,
        snr: 8.0,
        seed: 314,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.85);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.85, 0.35, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);

    let ssnal_res = ssnal::solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
    let cd_res = cd::solve_naive(&p, &BaselineOptions { tol: 1e-11, ..Default::default() });
    let fista_opts = BaselineOptions { tol: 1e-11, max_iters: 1_000_000, ..Default::default() };
    let fista_res = fista::solve_fista(&p, &fista_opts, true);
    assert!(ssnal_res.converged && cd_res.converged && fista_res.converged);

    let scale = 1.0 + blas::nrm2(&cd_res.x);
    for (solver, res) in [("ssnal", &ssnal_res), ("fista", &fista_res)] {
        let dist = blas::dist2(&res.x, &cd_res.x);
        assert!(dist / scale < 5e-4, "{solver} vs cd distance {dist}");
        assert!(
            (res.objective - cd_res.objective).abs()
                <= 1e-6 * (1.0 + cd_res.objective.abs()),
            "{solver} objective {} vs cd {}",
            res.objective,
            cd_res.objective
        );
    }
}
