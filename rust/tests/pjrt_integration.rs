//! Integration tests across the layer boundary: artifacts produced by
//! `python/compile/aot.py` (L2 JAX graphs embedding the L1 Pallas kernel) are
//! loaded and executed by the Rust PJRT runtime, and their numerics must match
//! the native f64 implementations to f32 tolerance.
//!
//! Requires `make artifacts` (the Makefile test target depends on it).

use ssnal_en::coordinator::{Coordinator, CoordinatorConfig};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::blas;
use ssnal_en::prox;
use ssnal_en::runtime::{literal_at, literal_from_f64, literal_scalar, literal_to_f64, PjrtEngine};
use ssnal_en::solver::types::EnetProblem;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    // tests run from the crate root
    let dir = ssnal_en::runtime::default_artifacts_dir();
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts missing at {} — run `make artifacts` first",
        dir.display()
    );
    dir
}

/// The small artifact shape produced by the default `make artifacts`.
const M: usize = 200;
const N: usize = 4096;

fn engine() -> PjrtEngine {
    PjrtEngine::load_dir(&artifacts_dir()).expect("engine should load all artifacts")
}

fn problem() -> ssnal_en::data::SyntheticProblem {
    generate_synthetic(&SyntheticSpec { m: M, n: N, n0: 10, x_star: 5.0, snr: 5.0, seed: 99 })
}

#[test]
fn engine_loads_manifest_and_graphs() {
    let e = engine();
    assert!(e.len() >= 6, "expected >= 6 graphs, got {}", e.len());
    assert_eq!(e.platform(), "cpu");
    assert!(e.graph("dual_prox_grad", M, N).is_ok());
    assert!(e.graph("hess_vec", M, N).is_ok());
    assert!(e.graph("dual_prox_grad", 1, 2).is_err(), "unknown shape must error");
}

#[test]
fn dual_prox_grad_graph_matches_native() {
    let e = engine();
    let prob = problem();
    let p = EnetProblem::new(&prob.a, &prob.b, 2.0, 1.0);
    let sigma = 0.05;

    // inputs
    let mut rng = ssnal_en::rng::Xoshiro256pp::seed_from_u64(5);
    let x: Vec<f64> = (0..N).map(|_| rng.next_gaussian()).collect();
    let y: Vec<f64> = (0..M).map(|_| rng.next_gaussian()).collect();

    // native f64 computation
    let aty = prob.a.t_mul_vec(&y);
    let t: Vec<f64> = (0..N).map(|j| x[j] - sigma * aty[j]).collect();
    let mut u = vec![0.0; N];
    prox::prox_enet(&t, sigma, p.lam1, p.lam2, &mut u);
    let au = prob.a.mul_vec(&u);
    let grad_native: Vec<f64> = (0..M).map(|i| y[i] + prob.b[i] - au[i]).collect();
    let psi_native = prox::h_star(&y, &prob.b)
        + (1.0 + sigma * p.lam2) / (2.0 * sigma) * blas::nrm2_sq(&u)
        - blas::nrm2_sq(&x) / (2.0 * sigma);

    // PJRT execution
    let g = e.graph("dual_prox_grad", M, N).unwrap();
    let outs = g
        .run(&[
            literal_at(&prob.a).unwrap(),
            literal_from_f64(&prob.b, &[M]).unwrap(),
            literal_from_f64(&x, &[N]).unwrap(),
            literal_from_f64(&y, &[M]).unwrap(),
            literal_scalar(sigma),
            literal_scalar(p.lam1),
            literal_scalar(p.lam2),
        ])
        .unwrap();
    assert_eq!(outs.len(), 4);
    let grad_pjrt = literal_to_f64(&outs[0]).unwrap();
    let u_pjrt = literal_to_f64(&outs[1]).unwrap();
    let mask_pjrt = literal_to_f64(&outs[2]).unwrap();
    let psi_pjrt = literal_to_f64(&outs[3]).unwrap()[0];

    // f32 tolerances, scaled by magnitudes
    let gscale = blas::nrm_inf(&grad_native) + 1.0;
    for i in 0..M {
        assert!(
            (grad_pjrt[i] - grad_native[i]).abs() < 1e-4 * gscale,
            "grad[{i}]: {} vs {}",
            grad_pjrt[i],
            grad_native[i]
        );
    }
    let uscale = blas::nrm_inf(&u) + 1.0;
    let mut mask_matches = 0;
    for j in 0..N {
        assert!((u_pjrt[j] - u[j]).abs() < 1e-4 * uscale, "u[{j}]");
        let native_active = t[j].abs() > sigma * p.lam1;
        if (mask_pjrt[j] > 0.5) == native_active {
            mask_matches += 1;
        }
    }
    // mask may differ only within f32 noise of the threshold
    assert!(mask_matches >= N - 5, "mask agreement {mask_matches}/{N}");
    assert!(
        (psi_pjrt - psi_native).abs() < 1e-3 * (1.0 + psi_native.abs()),
        "psi: {psi_pjrt} vs {psi_native}"
    );
}

#[test]
fn hess_vec_graph_matches_native() {
    let e = engine();
    let prob = problem();
    let mut rng = ssnal_en::rng::Xoshiro256pp::seed_from_u64(7);
    let d: Vec<f64> = (0..M).map(|_| rng.next_gaussian()).collect();
    let mask: Vec<f64> = (0..N).map(|_| if rng.next_f64() < 0.05 { 1.0 } else { 0.0 }).collect();
    let active: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &v)| v > 0.5).map(|(j, _)| j).collect();
    let kappa = 0.7;

    // native: d + κ A_J A_Jᵀ d
    let mut native = d.clone();
    for &j in &active {
        let c = kappa * blas::dot(prob.a.col(j), &d);
        blas::axpy(c, prob.a.col(j), &mut native);
    }

    let g = e.graph("hess_vec", M, N).unwrap();
    let outs = g
        .run(&[
            literal_at(&prob.a).unwrap(),
            literal_from_f64(&mask, &[N]).unwrap(),
            literal_scalar(kappa),
            literal_from_f64(&d, &[M]).unwrap(),
        ])
        .unwrap();
    let pjrt = literal_to_f64(&outs[0]).unwrap();
    let scale = blas::nrm_inf(&native) + 1.0;
    for i in 0..M {
        assert!((pjrt[i] - native[i]).abs() < 1e-4 * scale, "vd[{i}]");
    }
}

#[test]
fn al_update_graph_roundtrips() {
    let e = engine();
    let g = e.graph("al_update", M, N).unwrap();
    let x = vec![1.0; N];
    let u: Vec<f64> = (0..N).map(|j| (j % 7) as f64 * 0.25).collect();
    let outs = g
        .run(&[literal_from_f64(&x, &[N]).unwrap(), literal_from_f64(&u, &[N]).unwrap()])
        .unwrap();
    assert_eq!(outs.len(), 2, "al_update returns (x_next, dist)");
    let out = literal_to_f64(&outs[0]).unwrap();
    assert_eq!(out, u);
    let dist = literal_to_f64(&outs[1]).unwrap()[0];
    let expected = blas::dist2(&x, &u);
    assert!((dist - expected).abs() < 1e-3 * (1.0 + expected), "{dist} vs {expected}");
}

#[test]
fn pjrt_backend_solves_end_to_end_and_agrees_with_native() {
    // Full three-layer composition: Rust AL/SsN/CG control loop driving the
    // AOT-compiled JAX+Pallas graphs, vs the native f64 solver.
    let prob = problem();
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);

    let native = Coordinator::new(CoordinatorConfig::native(1e-8))
        .solve(&prob.a, &prob.b, l1, l2)
        .unwrap();
    let pjrt = Coordinator::new(CoordinatorConfig::pjrt(artifacts_dir()))
        .solve(&prob.a, &prob.b, l1, l2)
        .unwrap();

    assert!(pjrt.converged, "pjrt backend residual {}", pjrt.residual);
    // same support (up to threshold noise) and close coefficients
    let dist = blas::dist2(&native.x, &pjrt.x);
    let scale = blas::nrm2(&native.x) + 1.0;
    assert!(dist / scale < 1e-2, "native vs pjrt distance {dist} (scale {scale})");
    assert!(
        (native.objective - pjrt.objective).abs() < 1e-3 * (1.0 + native.objective),
        "objectives: {} vs {}",
        native.objective,
        pjrt.objective
    );
    // supports agree on confidently-nonzero coefficients
    for (j, &xn) in native.x.iter().enumerate() {
        if xn.abs() > 1e-2 * scale {
            assert!(pjrt.x[j] != 0.0, "pjrt missed native-active feature {j}");
        }
    }
}
