//! Integration tests for the runtime layer's *contract* side: the artifact
//! manifest produced by `python/compile/aot.py`, the `Literal` buffer
//! conventions shared with the L2 JAX graphs, and the engine's behavior in
//! this offline build (no XLA/PJRT binding is linked, so graph execution is
//! expected to degrade to a descriptive error — never a panic).
//!
//! Numerical graph-vs-native comparisons require a PJRT binding plus
//! `make artifacts`; those tests self-skip when either is unavailable.

use ssnal_en::api::{Backend, Design, EnetModel};
use ssnal_en::linalg::Mat;
use ssnal_en::runtime::{
    literal_at, literal_from_f64, literal_scalar, literal_to_f64, Manifest, PjrtEngine,
};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = ssnal_en::runtime::default_artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn literal_contract_roundtrips() {
    // f64 → f32 literal → f64, 1-D and scalar
    let vals = [0.5f64, -1.25, 3.0, 7.5];
    let lit = literal_from_f64(&vals, &[4]).unwrap();
    assert_eq!(lit.dims(), &[4]);
    assert_eq!(literal_to_f64(&lit).unwrap(), vals.to_vec());
    let s = literal_scalar(2.5);
    assert_eq!(s.dims(), &[] as &[usize]);
    assert_eq!(literal_to_f64(&s).unwrap(), vec![2.5]);
    // shape mismatches are errors, not panics
    assert!(literal_from_f64(&vals, &[3]).is_err());
    assert!(literal_from_f64(&vals, &[2, 3]).is_err());
}

#[test]
fn design_matrix_crosses_the_boundary_transposed() {
    // column-major Mat storage == row-major (n, m) == Aᵀ, no copy transpose
    let a = Mat::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let lit = literal_at(&a).unwrap();
    assert_eq!(lit.dims(), &[3, 2]);
    assert_eq!(literal_to_f64(&lit).unwrap(), vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
}

#[test]
fn manifest_parses_the_producer_format() {
    let text = r#"{
      "dtype": "f32",
      "artifacts": [
        {"name": "dual_prox_grad", "m": 200, "n": 4096, "file": "dual_prox_grad_200x4096.hlo.txt"},
        {"name": "hess_vec", "m": 200, "n": 4096, "file": "hess_vec_200x4096.hlo.txt"}
      ]
    }"#;
    let m = Manifest::parse(text, Path::new("/tmp/artifacts")).unwrap();
    assert_eq!(m.dtype, "f32");
    assert_eq!(m.shapes(), vec![(200, 4096)]);
    assert!(m.find("dual_prox_grad", 200, 4096).is_some());
    assert!(m.find("dual_prox_grad", 1, 2).is_none());
}

#[test]
fn engine_without_artifacts_errors_helpfully() {
    let err = PjrtEngine::load_dir(Path::new("/nonexistent_artifacts_xyz")).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn pjrt_backend_degrades_to_an_error_not_a_panic() {
    // Whether or not artifacts exist, this offline build has no PJRT binding:
    // a Pjrt-backend solve must return Err with actionable context.
    let dir = artifacts_dir().unwrap_or_else(|| PathBuf::from("/nonexistent_artifacts_xyz"));
    let a = Mat::zeros(2, 3);
    let b = [1.0, 2.0];
    let design = Design::new(&a, &b).unwrap();
    let err = EnetModel::new()
        .lambda(0.5, 0.5)
        .backend(Backend::Pjrt)
        .artifacts_dir(dir)
        .fit(&design)
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("artifacts"), "{msg}");
}

#[test]
fn engine_load_with_real_artifacts_if_present() {
    // With artifacts built (`make artifacts`), load_dir must either produce a
    // working engine (PJRT-enabled build) or the descriptive offline error —
    // silently wrong states (panic, empty engine) are the failure mode.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    match PjrtEngine::load_dir(&dir) {
        Ok(engine) => {
            assert!(engine.len() >= 2, "expected >= 2 graphs, got {}", engine.len());
            assert!(engine.graph("dual_prox_grad", 1, 2).is_err(), "unknown shape must error");
        }
        Err(e) => {
            let msg = format!("{e}");
            assert!(msg.contains("XLA") || msg.contains("PJRT"), "unexpected error: {msg}");
        }
    }
}
