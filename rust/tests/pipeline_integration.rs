//! End-to-end pipeline tests: data generation → standardization → path →
//! tuning → de-biasing, on each of the paper's three workload families
//! (synthetic §4.1, polynomial expansion Table 2, SNP/GWAS §4.2).

use ssnal_en::data::libsvm::{synthesize_base, ReferenceSet};
use ssnal_en::data::polyexp::{drop_constant_columns, expand};
use ssnal_en::data::snp::{generate as generate_snp, SnpSpec};
use ssnal_en::data::{center, generate_synthetic, rho_hat, standardize, SyntheticSpec};
use ssnal_en::path::{c_lambda_grid, solve_path, PathOptions};
use ssnal_en::solver::types::Algorithm;
use ssnal_en::tuning::{tune, TuningOptions};

#[test]
fn synthetic_pipeline_selects_truth_with_ebic() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 120,
        n: 1_500,
        n0: 6,
        x_star: 5.0,
        snr: 20.0,
        seed: 2,
    });
    let topts = TuningOptions {
        path: PathOptions {
            alpha: 0.9,
            c_grid: c_lambda_grid(0.95, 0.05, 25),
            max_active: 30,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        },
        cv_folds: 0,
        cv_seed: 0,
    };
    let tr = tune(&prob.a, &prob.b, &topts);
    let chosen = &tr.path.points[tr.best_ebic].result;
    // e-BIC should recover (nearly) exactly the truth at this SNR
    let hits = prob.support.iter().filter(|j| chosen.x[**j] != 0.0).count();
    assert!(hits >= 5, "e-bic model hits {hits}/6 true features");
    assert!(chosen.active_set.len() <= 12, "e-bic should stay parsimonious");
}

#[test]
fn polyexp_pipeline_handles_collinearity() {
    let base = synthesize_base(ReferenceSet::Housing, 3);
    let (clean, _) = drop_constant_columns(&base.a, 1e-9);
    let (expanded, _) = expand(&clean, 4, 3_000);
    let std = standardize(&expanded);
    let (b, _) = center(&base.b);
    // the expansion is heavily collinear — exactly the Elastic Net's regime
    let rho = rho_hat(&std.a, 30, 0);
    assert!(rho > 5.0, "expanded design should be collinear (ρ̂ = {rho})");
    // path must run to completion without numerical failure
    let path = solve_path(
        &std.a,
        &b,
        &PathOptions {
            alpha: 0.5,
            c_grid: c_lambda_grid(0.9, 0.2, 10),
            max_active: 40,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        },
    );
    assert!(path.runs >= 3);
    for p in &path.points {
        assert!(p.result.converged, "c={} did not converge", p.c_lambda);
    }
}

#[test]
fn snp_pipeline_finds_dominant_snp() {
    let spec = SnpSpec {
        m: 150,
        n_snps: 3_000,
        n_causal: 5,
        dominant_effect: 2.0,
        noise_sd: 0.6,
        seed: 4,
        ..Default::default()
    };
    let cohort = generate_snp(&spec);
    let topts = TuningOptions {
        path: PathOptions {
            alpha: 0.9,
            c_grid: c_lambda_grid(0.99, 0.1, 20),
            max_active: 25,
            tol: 1e-5,
            algorithm: Algorithm::SsnalEn,
        },
        cv_folds: 0,
        cv_seed: 0,
    };
    let tr = tune(&cohort.a, &cohort.b, &topts);
    // the paper's Figure 2 pattern: the first feature to enter the path is the
    // dominant SNP (active set of 1 at large λ)
    let first_active = tr
        .path
        .points
        .iter()
        .find(|p| !p.result.active_set.is_empty())
        .expect("someone must activate");
    assert_eq!(
        first_active.result.active_set.len(),
        1,
        "first path point with actives should have exactly 1 (the dominant SNP)"
    );
    assert_eq!(
        first_active.result.active_set[0], cohort.causal[0],
        "the first selected SNP should be the dominant causal one"
    );
    // and the e-BIC model should include it
    let chosen = &tr.path.points[tr.best_ebic].result;
    assert!(chosen.x[cohort.causal[0]] != 0.0);
}

#[test]
fn cv_and_information_criteria_are_consistent() {
    // On an easy problem all three §3.3 criteria should pick models in the
    // same sparsity ballpark.
    let prob = generate_synthetic(&SyntheticSpec {
        m: 60,
        n: 300,
        n0: 4,
        x_star: 5.0,
        snr: 25.0,
        seed: 6,
    });
    let topts = TuningOptions {
        path: PathOptions {
            alpha: 0.9,
            c_grid: c_lambda_grid(0.9, 0.1, 12),
            max_active: 20,
            tol: 1e-5,
            algorithm: Algorithm::SsnalEn,
        },
        cv_folds: 5,
        cv_seed: 1,
    };
    let tr = tune(&prob.a, &prob.b, &topts);
    let r_gcv = tr.points[tr.best_gcv].active;
    let r_ebic = tr.points[tr.best_ebic].active;
    let r_cv = tr.points[tr.best_cv.unwrap()].active;
    for (name, r) in [("gcv", r_gcv), ("ebic", r_ebic), ("cv", r_cv)] {
        assert!((2..=16).contains(&r), "{name} picked r={r}, expected near 4");
    }
}

#[test]
fn path_driver_agrees_between_algorithms_on_pipeline_data() {
    let base = synthesize_base(ReferenceSet::Bodyfat, 9);
    let (clean, _) = drop_constant_columns(&base.a, 1e-9);
    let (expanded, _) = expand(&clean, 3, 1_500);
    let std = standardize(&expanded);
    let (b, _) = center(&base.b);
    let grid = c_lambda_grid(0.9, 0.3, 6);
    let mk = |algorithm| PathOptions {
        alpha: 0.8,
        c_grid: grid.clone(),
        max_active: 0,
        tol: 1e-8,
        algorithm,
    };
    let ps = solve_path(&std.a, &b, &mk(Algorithm::SsnalEn));
    let pc = solve_path(&std.a, &b, &mk(Algorithm::CdCovariance));
    for (a, c) in ps.points.iter().zip(pc.points.iter()) {
        let dist = ssnal_en::linalg::blas::dist2(&a.result.x, &c.result.x);
        let scale = ssnal_en::linalg::blas::nrm2(&a.result.x) + 1.0;
        assert!(dist / scale < 1e-3, "c={}: dist {dist}", a.c_lambda);
    }
}
