//! Zero-allocation pin for the workspace-backed Newton hot path (ISSUE 4
//! criterion): with the counting allocator installed as this test binary's
//! `#[global_allocator]`, steady-state Newton-system solves — warm workspace,
//! unchanged active set and κ, 1-thread shard budget (single-shard serial
//! kernel paths) — must perform **zero** heap allocations, for every
//! strategy. A companion bound pins a fully-warm end-to-end SsNAL re-solve to
//! a small constant allocation count (its per-solve state vectors), so no
//! per-iteration churn can hide in the outer loop. ISSUE 9 extends the
//! zero pins to the screened warm-chain steady state: sub-design retargeting
//! and rank-1 active-set edit cycling must also allocate nothing.
//!
//! The counter is process-global and the harness runs a binary's tests on
//! several threads, so two defenses keep the pins deterministic: every test
//! in this binary serializes on [`GATE`] (no concurrent test *bodies*), and
//! each measured region takes the **minimum delta over a few attempts** —
//! the libtest harness's own threads may allocate bookkeeping at arbitrary
//! moments outside the gate's reach, but that noise is transient, while a
//! genuine hot-path allocation shows up in every attempt. Measured regions
//! run with the shard budget pinned to 1 (no pool traffic).

use ssnal_en::api::{Design, EnetModel};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{Mat, NewtonWorkspace};
use ssnal_en::parallel::shard;
use ssnal_en::rng::Xoshiro256pp;
use ssnal_en::solver::ssn_system::solve_newton_system_ws;
use ssnal_en::solver::types::{EnetProblem, NewtonStrategy, SsnalOptions};
use ssnal_en::util::alloc_count::{allocations, CountingAllocator};
use std::sync::Mutex;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Serializes the whole binary's tests: a concurrent test's allocations
/// would otherwise leak into another's measured window.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Minimum allocation delta of `region` over a few attempts (see the module
/// docs: harness-thread noise is transient, real leaks repeat every time).
fn min_allocs(mut region: impl FnMut()) -> u64 {
    let mut min = u64::MAX;
    for _ in 0..3 {
        let before = allocations();
        region();
        min = min.min(allocations() - before);
        if min == 0 {
            break;
        }
    }
    min
}

fn newton_case(m: usize, n: usize, r: usize, seed: u64) -> (Mat, Vec<usize>, Vec<f64>) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let active = rng.sample_indices(n, r);
    let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    (a, active, rhs)
}

/// Warm the workspace, then count allocations over repeated identical solves.
fn steady_state_allocs(strategy: NewtonStrategy, m: usize, n: usize, r: usize) -> u64 {
    let (a, active, rhs) = newton_case(m, n, r, 0xA110C);
    shard::with_threads(1, || {
        let mut ws = NewtonWorkspace::new();
        let mut d = vec![0.0; m];
        let solve = |ws: &mut NewtonWorkspace, d: &mut [f64]| {
            solve_newton_system_ws(&a, &active, 0.7, &rhs, d, strategy, 1e-10, 500, ws);
        };
        // warm-up: grow every buffer and populate the factorization cache
        solve(&mut ws, &mut d);
        solve(&mut ws, &mut d);
        min_allocs(|| {
            for _ in 0..10 {
                solve(&mut ws, &mut d);
            }
        })
    })
}

#[test]
fn steady_state_direct_newton_allocates_nothing() {
    let _serial = gate();
    assert_eq!(steady_state_allocs(NewtonStrategy::Direct, 60, 200, 25), 0);
}

#[test]
fn steady_state_woodbury_newton_allocates_nothing() {
    let _serial = gate();
    assert_eq!(steady_state_allocs(NewtonStrategy::Woodbury, 60, 300, 20), 0);
}

#[test]
fn steady_state_cg_newton_allocates_nothing() {
    let _serial = gate();
    assert_eq!(steady_state_allocs(NewtonStrategy::ConjugateGradient, 60, 300, 20), 0);
}

/// κ changes (a new outer AL iteration) refactor from the cached raw Gram —
/// still without allocating, since the factor buffer is dimension-stable.
#[test]
fn kappa_bumps_refactor_without_allocating() {
    let _serial = gate();
    let (a, active, rhs) = newton_case(50, 250, 18, 0x5E7);
    shard::with_threads(1, || {
        let mut ws = NewtonWorkspace::new();
        let mut d = vec![0.0; 50];
        for warmup_kappa in [0.5, 2.5] {
            solve_newton_system_ws(
                &a,
                &active,
                warmup_kappa,
                &rhs,
                &mut d,
                NewtonStrategy::Woodbury,
                1e-10,
                500,
                &mut ws,
            );
        }
        let delta = min_allocs(|| {
            for i in 0..10 {
                let kappa = if i % 2 == 0 { 0.5 } else { 2.5 };
                solve_newton_system_ws(
                    &a,
                    &active,
                    kappa,
                    &rhs,
                    &mut d,
                    NewtonStrategy::Woodbury,
                    1e-10,
                    500,
                    &mut ws,
                );
            }
        });
        assert_eq!(delta, 0, "κ-alternating Woodbury solves allocated");
    });
}

/// ISSUE 5 satellite: a warm `Fit::refit` on the facade session must allocate
/// strictly less than a cold `EnetModel::fit` of the same (design, response)
/// pair — the session reuses the Newton workspace buffers and the
/// Gram/Cholesky cache, while producing bitwise-identical results (pinned in
/// `tests/api_facade.rs`). Measured at a 1-thread shard budget like every
/// other pin in this binary (pool dispatch allocates).
#[test]
fn warm_refit_allocates_strictly_less_than_cold_fit() {
    let _serial = gate();
    let prob = generate_synthetic(&SyntheticSpec {
        m: 50,
        n: 400,
        n0: 6,
        x_star: 5.0,
        snr: 5.0,
        seed: 9,
    });
    let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
    shard::with_threads(1, || {
        let design = Design::new(&prob.a, &prob.b).unwrap();
        let design2 = Design::new(&prob.a, &b2).unwrap();
        let model = EnetModel::new().alpha_c(0.8, 0.4).tol(1e-6);
        let mut fit = model.fit(&design).unwrap();
        // prime the session on the refit response once so the measured
        // region is the steady serve-many-responses state
        fit.refit(&b2).unwrap();
        let warm = min_allocs(|| {
            fit.refit(&b2).unwrap();
        });
        let cold = min_allocs(|| {
            let f = model.fit(&design2).unwrap();
            std::hint::black_box(f.result().objective);
        });
        assert!(
            warm < cold,
            "warm refit allocated {warm} times, cold fit {cold} — the session \
             is not reusing its workspace"
        );
    });
}

/// ISSUE 9 satellite: the screened warm-chain hot path — retargeting the
/// workspace onto a gathered survivor sub-design, then solving — must be
/// allocation-free in steady state. When every cached column survives, the
/// retarget is a fingerprint rewrite plus an in-place index translation and
/// the factorization carries over untouched.
#[test]
fn screened_retarget_and_solve_allocate_nothing() {
    let _serial = gate();
    let (a, _, rhs) = newton_case(60, 300, 20, 0x5C12);
    let survivors: Vec<usize> = (0..150).map(|k| 2 * k).collect();
    let a_sub = a.gather_cols(&survivors);
    // active indices *within the sub-design*
    let active: Vec<usize> = vec![3, 11, 27, 40, 66, 90, 120];
    shard::with_threads(1, || {
        let mut ws = NewtonWorkspace::new();
        let mut d = vec![0.0; 60];
        let solve = |ws: &mut NewtonWorkspace, d: &mut [f64]| {
            solve_newton_system_ws(
                &a_sub,
                &active,
                0.7,
                &rhs,
                d,
                NewtonStrategy::Woodbury,
                1e-10,
                500,
                ws,
            );
        };
        // warm-up: populate the cache and ratchet the retarget scratch
        solve(&mut ws, &mut d);
        ws.retarget_columns((&a_sub).into(), Some);
        solve(&mut ws, &mut d);
        let delta = min_allocs(|| {
            for _ in 0..8 {
                // per λ point in a screened chain: retarget (all survive
                // here), then solve — the steady state of the warm chain
                ws.retarget_columns((&a_sub).into(), Some);
                solve(&mut ws, &mut d);
            }
        });
        assert_eq!(delta, 0, "screened retarget+solve steady state allocated");
    });
}

/// ISSUE 9 satellite: cycling between two overlapping active sets — the
/// rank-1 up/down-date tier's bread and butter (an interior column leaves,
/// another enters, every few λ steps) — must also be allocation-free once
/// buffer capacities have ratcheted: the Gram remap is in place, the edit
/// map is reused scratch, and the edited refactor is dimension-stable.
#[test]
fn rank1_edit_cycling_allocates_nothing() {
    let _serial = gate();
    let (a, _, rhs) = newton_case(60, 300, 20, 0xED17);
    let set_a: Vec<usize> = (0..18).map(|k| 4 * k).collect();
    let mut set_b = set_a.clone();
    set_b[9] = 37; // 36 → 37: one interior remove + one insert per switch
    shard::with_threads(1, || {
        let mut ws = NewtonWorkspace::new();
        let mut d = vec![0.0; 60];
        let solve = |ws: &mut NewtonWorkspace, active: &[usize], d: &mut [f64]| {
            solve_newton_system_ws(
                &a,
                active,
                0.7,
                &rhs,
                d,
                NewtonStrategy::Woodbury,
                1e-10,
                500,
                ws,
            );
        };
        // warm-up: both sets seen once, edit scratch and factor sized
        solve(&mut ws, &set_a, &mut d);
        solve(&mut ws, &set_b, &mut d);
        solve(&mut ws, &set_a, &mut d);
        let before = ws.stats;
        let delta = min_allocs(|| {
            for i in 0..8 {
                let active = if i % 2 == 0 { &set_b } else { &set_a };
                solve(&mut ws, active, &mut d);
            }
        });
        assert_eq!(delta, 0, "rank-1 edit cycling allocated in steady state");
        // the measured region really exercised the edit tier
        let edited = ws.stats.rank1_updates - before.rank1_updates;
        assert!(edited >= 8, "edit tier did not engage: {:?}", ws.stats);
    });
}

/// End-to-end bound: re-solving an already-converged problem on a warm
/// workspace performs only the per-solve state-vector setup — a small
/// constant, independent of iteration count. (The Newton kernels themselves
/// are pinned to exactly zero above; this catches per-iteration churn
/// anywhere else in the solver loop.)
#[test]
fn warm_resolve_allocations_are_bounded_setup_only() {
    let _serial = gate();
    let prob = generate_synthetic(&SyntheticSpec {
        m: 50,
        n: 400,
        n0: 6,
        x_star: 5.0,
        snr: 5.0,
        seed: 9,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.4, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let opts = SsnalOptions::default();
    shard::with_threads(1, || {
        let mut ws = NewtonWorkspace::new();
        let (first, _) = ssnal_en::solver::ssnal::solve_warm_ws(&p, &opts, None, &mut ws);
        assert!(first.converged);
        // warm re-solve from the solution: ~1 outer iteration
        let (again, _) =
            ssnal_en::solver::ssnal::solve_warm_ws(&p, &opts, Some(&first.x), &mut ws);
        assert!(again.converged);
        let delta = min_allocs(|| {
            let (res, _) =
                ssnal_en::solver::ssnal::solve_warm_ws(&p, &opts, Some(&first.x), &mut ws);
            assert!(res.converged);
        });
        assert!(
            delta <= 64,
            "warm re-solve allocated {delta} times — per-iteration churn crept back in"
        );
    });
}
