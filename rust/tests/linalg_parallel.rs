//! Property tests for the within-solve sharded linalg engine
//! (`ssnal_en::parallel::shard`), pinning the determinism contract for
//! random shapes — including lengths below the unroll width, empty inputs,
//! and non-multiple-of-8 tails — at 1, 2, 4 and 8 threads (ISSUE 2
//! criterion): every kernel is **bitwise thread-count-invariant** for a
//! fixed plan, element-wise kernels (`Aᵀy`, Gram) are additionally
//! bitwise-equal to the serial `Mat`/`blas` loops at any shard count, and
//! reduction kernels (`dot`, `A_J x`) are bitwise-equal to serial at
//! single-shard plans. ISSUE 3 extends the contract to the Gap-Safe
//! `dual_point`/survivor scoring sweeps, the direct-Newton rank-1 triangle
//! build, and kernel reuse on the warm persistent pool.

use ssnal_en::data::snp::{generate_sparse, SnpSpec, SparseSnpSpec};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{blas, CscMat, DesignRef, DesignStorage, Mat, NewtonWorkspace, OocDesign};
use ssnal_en::parallel::shard::{self, Plan};
use ssnal_en::rng::Xoshiro256pp;
use ssnal_en::solver::screening::AugmentedView;
use ssnal_en::solver::ssn_system::{solve_newton_system, solve_newton_system_ws};
use ssnal_en::solver::types::{EnetProblem, NewtonStrategy, SsnalOptions};
use ssnal_en::util::quickcheck::{log_uniform_usize, run_prop, PropConfig};

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn random_vec(rng: &mut Xoshiro256pp, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.next_gaussian()).collect()
}

/// Lengths that stress every code path: empty, below the 8-wide unroll,
/// exactly one unroll block, and ragged tails around shard boundaries.
fn edge_lengths() -> Vec<usize> {
    vec![0, 1, 2, 5, 7, 8, 9, 15, 16, 17, 31, 63, 64, 65, 100, 257]
}

#[test]
fn sharded_dot_is_bitwise_thread_invariant() {
    run_prop(
        PropConfig { cases: 48, seed: 0xD07 },
        |rng| {
            let len = log_uniform_usize(rng, 1, 5000) - 1; // include 0
            let a = random_vec(rng, len);
            let b = random_vec(rng, len);
            let shards = [1usize, 2, 3, 8][rng.next_below(4)];
            (a, b, shards)
        },
        |(a, b, shards)| {
            let plan = Plan::with_shards(*shards);
            let reference = shard::with_threads(1, || shard::dot_planned(plan, a, b));
            for &t in &THREADS {
                let got = shard::with_threads(t, || shard::dot_planned(plan, a, b));
                if got.to_bits() != reference.to_bits() {
                    return Err(format!(
                        "dot len={} shards={shards} threads={t}: {got:e} vs {reference:e}",
                        a.len()
                    ));
                }
            }
            // a single shard is the serial kernel, bit for bit
            let serial = blas::dot(a, b);
            let one = shard::dot_planned(Plan::single(), a, b);
            if one.to_bits() != serial.to_bits() {
                return Err(format!("single-shard dot differs from blas::dot: {one:e}"));
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_axpy_is_bitwise_serial_at_every_plan() {
    run_prop(
        PropConfig { cases: 48, seed: 0xA21 },
        |rng| {
            let len = log_uniform_usize(rng, 1, 4000) - 1;
            let alpha = rng.next_gaussian();
            let x = random_vec(rng, len);
            let y = random_vec(rng, len);
            let shards = 1 + rng.next_below(8);
            (alpha, x, y, shards)
        },
        |(alpha, x, y, shards)| {
            let mut serial = y.clone();
            blas::axpy(*alpha, x, &mut serial);
            for &t in &THREADS {
                let mut got = y.clone();
                shard::with_threads(t, || {
                    shard::axpy_planned(Plan::with_shards(*shards), *alpha, x, &mut got)
                });
                if got != serial {
                    return Err(format!(
                        "axpy len={} shards={shards} threads={t} diverged",
                        x.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_t_mul_vec_matches_serial_bitwise() {
    run_prop(
        PropConfig { cases: 32, seed: 0x7A1 },
        |rng| {
            let m = log_uniform_usize(rng, 1, 60);
            let n = log_uniform_usize(rng, 1, 400);
            let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
            let y = random_vec(rng, m);
            let shards = 1 + rng.next_below(8);
            (a, y, shards)
        },
        |(a, y, shards)| {
            let mut serial = vec![0.0; a.cols()];
            a.t_mul_vec_into(y, &mut serial);
            for &t in &THREADS {
                let mut got = vec![0.0; a.cols()];
                shard::with_threads(t, || {
                    shard::t_mul_vec_into_planned(Plan::with_shards(*shards), a, y, &mut got)
                });
                if got != serial {
                    return Err(format!(
                        "Aᵀy {}×{} shards={shards} threads={t} diverged",
                        a.rows(),
                        a.cols()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_support_mat_vec_is_thread_invariant() {
    run_prop(
        PropConfig { cases: 32, seed: 0x5B2 },
        |rng| {
            let m = log_uniform_usize(rng, 1, 50);
            let n = log_uniform_usize(rng, 1, 300);
            let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
            let x = random_vec(rng, n);
            let support = rng.sample_indices(n, (n / 3).max(1).min(n));
            let shards = 1 + rng.next_below(8);
            (a, x, support, shards)
        },
        |(a, x, support, shards)| {
            let plan = Plan::with_shards(*shards);
            let reference = shard::with_threads(1, || {
                let mut out = vec![0.0; a.rows()];
                shard::mul_vec_support_into_planned(plan, a, x, support, &mut out);
                out
            });
            for &t in &THREADS {
                let got = shard::with_threads(t, || {
                    let mut out = vec![0.0; a.rows()];
                    shard::mul_vec_support_into_planned(plan, a, x, support, &mut out);
                    out
                });
                if got != reference {
                    return Err(format!("A_J x shards={shards} threads={t} diverged"));
                }
            }
            // single shard ≡ the serial Mat kernel
            let mut serial = vec![0.0; a.rows()];
            a.mul_vec_support_into(x, support, &mut serial);
            let mut one = vec![0.0; a.rows()];
            shard::mul_vec_support_into_planned(Plan::single(), a, x, support, &mut one);
            if one != serial {
                return Err("single-shard A_J x differs from serial".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_add_scaled_cols_is_thread_invariant() {
    run_prop(
        PropConfig { cases: 32, seed: 0xAD5 },
        |rng| {
            let m = log_uniform_usize(rng, 1, 40);
            let n = log_uniform_usize(rng, 1, 200);
            let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
            let r = (n / 2).max(1).min(n);
            let idx = rng.sample_indices(n, r);
            // include exact zeros: the kernels must skip them identically
            let coeffs: Vec<f64> = (0..r)
                .map(|_| if rng.next_below(5) == 0 { 0.0 } else { rng.next_gaussian() })
                .collect();
            let base = random_vec(rng, m);
            let shards = 1 + rng.next_below(8);
            (a, idx, coeffs, base, shards)
        },
        |(a, idx, coeffs, base, shards)| {
            let plan = Plan::with_shards(*shards);
            let reference = shard::with_threads(1, || {
                let mut out = base.clone();
                shard::add_scaled_cols_planned(plan, a, idx, coeffs, &mut out);
                out
            });
            for &t in &THREADS {
                let got = shard::with_threads(t, || {
                    let mut out = base.clone();
                    shard::add_scaled_cols_planned(plan, a, idx, coeffs, &mut out);
                    out
                });
                if got != reference {
                    return Err(format!("A_J w shards={shards} threads={t} diverged"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_gram_matches_serial_bitwise_when_it_fans_out() {
    // big enough that Plan::for_work actually multi-shards the build
    let mut rng = Xoshiro256pp::seed_from_u64(404);
    let m = 50;
    let n = 320;
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let idx: Vec<usize> = (0..n).collect();
    let serial = a.gram_of_cols(&idx, 0.7);
    for &t in &THREADS {
        let got = shard::with_threads(t, || shard::gram_of_cols(&a, &idx, 0.7));
        assert_eq!(got.as_slice(), serial.as_slice(), "gram diverged at threads={t}");
        assert_eq!(got.rows(), serial.rows());
    }
}

#[test]
fn edge_lengths_cover_tails_and_empty() {
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    for len in edge_lengths() {
        let a = random_vec(&mut rng, len);
        let b = random_vec(&mut rng, len);
        let serial_dot = blas::dot(&a, &b);
        for shards in [1usize, 2, 3, 8] {
            let plan = Plan::with_shards(shards);
            let reference = shard::with_threads(1, || shard::dot_planned(plan, &a, &b));
            for &t in &THREADS {
                let got = shard::with_threads(t, || shard::dot_planned(plan, &a, &b));
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "dot len={len} shards={shards} threads={t}"
                );
            }
            // degenerate splits (≤ 1 element, or one shard) are the serial
            // kernel, bit for bit
            if len <= 1 || shards == 1 {
                assert_eq!(reference.to_bits(), serial_dot.to_bits(), "len={len}");
            }

            let mut serial_axpy = b.clone();
            blas::axpy(0.5, &a, &mut serial_axpy);
            let mut got = b.clone();
            shard::with_threads(4, || shard::axpy_planned(plan, 0.5, &a, &mut got));
            assert_eq!(got, serial_axpy, "axpy len={len} shards={shards}");
        }
    }
}

/// The Gap-Safe scoring sweeps (`dual_point`'s ‖Ãᵀr̃‖∞ scan and the survivor
/// scan) now shard over the pool: at a shape big enough to fan out, every
/// output — dual value, scaled dual point, survivor index set — must be
/// bitwise-identical at 1/2/4/8 threads (ISSUE 3 criterion).
#[test]
fn dual_point_and_survivors_are_bitwise_thread_invariant() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 100,
        n: 30_000,
        n0: 10,
        x_star: 5.0,
        snr: 8.0,
        seed: 21,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.4, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    // the scoring sweeps must actually multi-shard at this shape, or the
    // test would pass vacuously
    assert!(Plan::for_work(30_000, 2 * 100).shards > 1);
    // screen at a crude iterate so the survivor set is non-trivial
    let x: Vec<f64> = prob.x_true.iter().map(|v| v * 0.5).collect();

    let aug = AugmentedView::new(&p);
    let ((dual_ref, top_ref, bottom_ref), surv_ref) =
        shard::with_threads(1, || (aug.dual_point(&x), aug.gap_safe_survivors(&x)));
    assert!(!surv_ref.is_empty(), "safe rule must keep the signal features");
    for t in [2usize, 4, 8] {
        let ((dual, top, bottom), surv) =
            shard::with_threads(t, || (aug.dual_point(&x), aug.gap_safe_survivors(&x)));
        assert_eq!(dual.to_bits(), dual_ref.to_bits(), "dual value drifted at threads={t}");
        assert_eq!(top, top_ref, "θ_top drifted at threads={t}");
        assert_eq!(bottom, bottom_ref, "θ_bottom drifted at threads={t}");
        assert_eq!(surv, surv_ref, "survivor set drifted at threads={t}");
    }
}

/// The direct Newton strategy's m×m rank-1 triangle build now shards over
/// the pool: at a shape where its plan multi-shards, the solved direction is
/// bitwise-identical at every thread budget.
#[test]
fn direct_newton_build_is_bitwise_thread_invariant() {
    let mut rng = Xoshiro256pp::seed_from_u64(99);
    let (m, n, r) = (200, 600, 150);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let active = rng.sample_indices(n, r);
    let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    assert!(Plan::for_work(m * (m + 1) / 2, 2 * r).shards > 1, "build must fan out");

    let solve = || {
        let mut d = vec![0.0; m];
        solve_newton_system(&a, &active, 0.7, &rhs, &mut d, NewtonStrategy::Direct, 1e-10, 100);
        d
    };
    let reference = shard::with_threads(1, solve);
    for t in [2usize, 4, 8] {
        let got = shard::with_threads(t, solve);
        assert_eq!(got, reference, "direct Newton solve drifted at threads={t}");
    }
}

/// Pool-reuse guarantee: repeated kernel calls on a warm persistent pool
/// keep producing the bits of the 1-thread (fresh) run — dispatch reuse must
/// never leak state between batches.
#[test]
fn warm_pool_kernel_calls_repeat_identically() {
    let mut rng = Xoshiro256pp::seed_from_u64(55);
    let a: Vec<f64> = (0..6000).map(|_| rng.next_gaussian()).collect();
    let b: Vec<f64> = (0..6000).map(|_| rng.next_gaussian()).collect();
    let plan = Plan::with_shards(8);
    let reference = shard::with_threads(1, || shard::dot_planned(plan, &a, &b));
    for call in 0..20 {
        let got = shard::with_threads(4, || shard::dot_planned(plan, &a, &b));
        assert_eq!(got.to_bits(), reference.to_bits(), "warm-pool call {call} drifted");
    }
}

/// Scratch-reuse guarantee for the partial-buffer reduction kernels
/// (ISSUE 4): repeated multi-shard `A_J x` / `A_J w` / Gram / rank-1 calls
/// draw their per-shard partials from the calling thread's warm
/// `ShardScratch` arena — every repeat, at every thread budget on the warm
/// pool, must reproduce the 1-thread bits (a stale, mis-zeroed or mis-sized
/// scratch buffer would corrupt exactly these kernels).
#[test]
fn warm_scratch_reduction_kernels_repeat_identically() {
    let mut rng = Xoshiro256pp::seed_from_u64(606);
    let (m, n) = (40, 240);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    let support: Vec<usize> = (0..n).step_by(2).collect();
    let coeffs: Vec<f64> = support.iter().map(|&j| x[j] * 0.5).collect();
    let plan = Plan::with_shards(8);
    // Gram/rank-1 size their own plans; at this shape the default flop
    // target would keep them single-shard (serial, scratch-free) and make
    // their legs vacuous — pin the target low so they genuinely fan out.
    let r = support.len();
    shard::with_target_shard_flops(shard::MIN_SHARD_FLOPS, || {
        assert!(Plan::for_work(r * (r + 1) / 2, 2 * m).shards > 1, "gram leg must fan out");
        assert!(Plan::for_work(m * (m + 1) / 2, 2 * r).shards > 1, "rank-1 leg must fan out");
    });
    let run_kernels = || {
        shard::with_target_shard_flops(shard::MIN_SHARD_FLOPS, || {
            let mut au = vec![0.0; m];
            shard::mul_vec_support_into_planned(plan, &a, &x, &support, &mut au);
            let mut acc = x[..m].to_vec();
            shard::add_scaled_cols_planned(plan, &a, &support, &coeffs, &mut acc);
            let gram = shard::gram_of_cols(&a, &support, 0.4);
            let mut v = Mat::zeros(m, m);
            shard::rank1_lower_accum(&a, &support, 0.9, &mut v);
            (au, acc, gram, v)
        })
    };

    let reference = shard::with_threads(1, run_kernels);
    for call in 0..10 {
        for &t in &THREADS {
            let got = shard::with_threads(t, run_kernels);
            assert_eq!(got.0, reference.0, "A_J x drifted (call {call}, threads {t})");
            assert_eq!(got.1, reference.1, "A_J w drifted (call {call}, threads {t})");
            assert_eq!(
                got.2.as_slice(),
                reference.2.as_slice(),
                "gram drifted (call {call}, threads {t})"
            );
            assert_eq!(
                got.3.as_slice(),
                reference.3.as_slice(),
                "rank-1 triangle drifted (call {call}, threads {t})"
            );
        }
    }
}

/// Warm Gram/Cholesky cache contract (ISSUE 4): along a λ-path-like sequence
/// of Newton solves — stable active set, κ bump, tail swap, growth,
/// shrink — a single warm workspace must produce, at every thread budget on
/// the warm pool, exactly the bits of a cold (fresh-workspace) solve of each
/// step. Shapes are chosen so the Gram/rank-1 builds genuinely multi-shard.
#[test]
fn warm_newton_cache_is_bitwise_cold_at_every_thread_budget() {
    let mut rng = Xoshiro256pp::seed_from_u64(404_404);
    let (m, n, r) = (200, 600, 150);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    assert!(Plan::for_work(m * (m + 1) / 2, 2 * r).shards > 1, "rank-1 build must fan out");
    assert!(Plan::for_work(r * (r + 1) / 2, 2 * m).shards > 1, "gram build must fan out");

    // base covers multiples of 4 below n; replacements use odd indices that
    // cannot collide with it
    let base: Vec<usize> = (0..r).map(|k| 4 * k).collect();
    let mut swapped = base.clone();
    swapped[r - 2] = n - 3;
    swapped[r - 1] = n - 1;
    let mut grown = swapped.clone();
    grown.push(n - 5);
    let shrunk: Vec<usize> = grown[..r - 4].to_vec();
    let steps: Vec<(Vec<usize>, f64)> = vec![
        (base.clone(), 0.7),
        (base.clone(), 0.7), // exact repeat → full factor hit
        (base.clone(), 2.1), // κ bump → raw-Gram reuse
        (swapped, 2.1),      // tail swap → incremental + partial refactor
        (grown, 2.1),        // growth → incremental, dimension change
        (shrunk, 0.9),       // shrink + κ change
    ];

    for strategy in [NewtonStrategy::Direct, NewtonStrategy::Woodbury] {
        let run_warm = |steps: &[(Vec<usize>, f64)]| {
            let mut ws = NewtonWorkspace::new();
            let mut out = Vec::new();
            for (active, kappa) in steps {
                let mut d = vec![0.0; m];
                solve_newton_system_ws(
                    &a, active, *kappa, &rhs, &mut d, strategy, 1e-10, 500, &mut ws,
                );
                out.push(d);
            }
            (out, ws.stats)
        };
        let (reference, stats) = shard::with_threads(1, || run_warm(&steps));
        // the cache must actually engage, or this test is vacuous
        match strategy {
            NewtonStrategy::Direct => assert!(stats.direct_hits >= 1, "{stats:?}"),
            _ => {
                assert!(stats.factor_hits >= 1, "{stats:?}");
                assert!(stats.gram_hits >= 1, "{stats:?}");
                assert!(stats.gram_incremental >= 2, "{stats:?}");
                assert!(stats.partial_refactors >= 1, "{stats:?}");
            }
        }
        // warm sequence is invariant to the thread budget (warm pool)
        for t in [2usize, 4, 8] {
            let (got, _) = shard::with_threads(t, || run_warm(&steps));
            assert_eq!(got, reference, "{strategy:?} warm sequence drifted at threads={t}");
        }
        // every warm step equals a cold fresh-workspace solve, bit for bit
        for (k, (active, kappa)) in steps.iter().enumerate() {
            let cold = shard::with_threads(1, || {
                let mut d = vec![0.0; m];
                solve_newton_system(&a, active, *kappa, &rhs, &mut d, strategy, 1e-10, 500);
                d
            });
            assert_eq!(cold, reference[k], "{strategy:?} step {k}: warm != cold");
        }
    }
}

/// Rank-1 edit-tier contract (ISSUE 9): along a λ-path-like sequence whose
/// active set changes by a few columns at a time — interior swap, interior
/// multi-column downdate, suffix append — the structurally up/down-dated
/// Gram/Cholesky factors must produce, at every thread budget on the warm
/// pool, exactly the bits of a cold (fresh-workspace) solve of each step,
/// and the rank-1 counters must actually engage (or the test is vacuous).
#[test]
fn rank1_edited_factors_are_bitwise_cold_at_every_thread_budget() {
    let mut rng = Xoshiro256pp::seed_from_u64(909_909);
    let (m, n, r) = (200, 600, 150);
    let a = Mat::from_fn(m, n, |_, _| rng.next_gaussian());
    let rhs: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    assert!(Plan::for_work(m * (m + 1) / 2, 2 * r).shards > 1, "rank-1 build must fan out");
    assert!(Plan::for_work(r * (r + 1) / 2, 2 * m).shards > 1, "gram build must fan out");

    // base covers multiples of 4; edits use odd indices that cannot collide
    let base: Vec<usize> = (0..r).map(|k| 4 * k).collect();
    let mut swapped = base.clone();
    swapped[40] = 161; // 160 → 161: one interior remove + one insert
    let mut pruned = swapped.clone();
    pruned.drain(120..124); // four interior removals, pure downdate
    let mut grown = pruned.clone();
    grown.extend([n - 2, n - 1]); // suffix append of two columns
    let steps: Vec<(Vec<usize>, f64)> = vec![
        (base, 0.7),          // cold rebuild
        (swapped, 0.7),       // edit tier: 1 up + 1 down, partial refactor
        (pruned, 0.7),        // edit tier: 4-column downdate
        (grown.clone(), 0.7), // edit tier: suffix append (direct: serial fold)
        (grown.clone(), 2.1), // κ bump → raw-Gram reuse
        (grown, 2.1),         // exact repeat → full factor hit
    ];

    for strategy in [NewtonStrategy::Direct, NewtonStrategy::Woodbury] {
        let run_warm = |steps: &[(Vec<usize>, f64)]| {
            let mut ws = NewtonWorkspace::new();
            let mut out = Vec::new();
            for (active, kappa) in steps {
                let mut d = vec![0.0; m];
                solve_newton_system_ws(
                    &a, active, *kappa, &rhs, &mut d, strategy, 1e-10, 500, &mut ws,
                );
                out.push(d);
            }
            (out, ws.stats)
        };
        let (reference, stats) = shard::with_threads(1, || run_warm(&steps));
        // the edit tier must actually engage, or this test is vacuous
        match strategy {
            NewtonStrategy::Direct => {
                assert!(stats.rank1_updates >= 2, "{stats:?}"); // suffix append
                assert!(stats.direct_hits >= 1, "{stats:?}");
            }
            _ => {
                assert!(stats.rank1_updates >= 3, "{stats:?}"); // 1 + 2
                assert!(stats.rank1_downdates >= 5, "{stats:?}"); // 1 + 4
                assert!(stats.partial_refactors >= 2, "{stats:?}");
                assert!(stats.factor_hits >= 1, "{stats:?}");
                assert!(stats.gram_hits >= 1, "{stats:?}");
            }
        }
        assert_eq!(stats.downdate_fallbacks, 0, "{stats:?}");
        // warm edited sequence is invariant to the thread budget (warm pool)
        for t in [2usize, 4, 8] {
            let (got, _) = shard::with_threads(t, || run_warm(&steps));
            assert_eq!(got, reference, "{strategy:?} edited sequence drifted at threads={t}");
        }
        // every warm step equals a cold fresh-workspace solve, bit for bit
        for (k, (active, kappa)) in steps.iter().enumerate() {
            let cold = shard::with_threads(1, || {
                let mut d = vec![0.0; m];
                solve_newton_system(&a, active, *kappa, &rhs, &mut d, strategy, 1e-10, 500);
                d
            });
            assert_eq!(cold, reference[k], "{strategy:?} step {k}: edited warm != cold");
        }
    }
}

/// The downdate → fallback boundary: when an edited refactor genuinely loses
/// positive definiteness (here: κ < 0 makes the Woodbury ridge negative and
/// the edit inserts an exact duplicate column, so `G + κ⁻¹I` has a −0.5
/// eigenvalue), the workspace must count one `downdate_fallbacks`, retry the
/// factorization cold (which fails identically), fall back to CG — and then
/// recover on the next well-posed solve by reusing the still-valid raw Gram,
/// bitwise-identical to cold, at every thread budget.
#[test]
fn downdate_fallback_recovers_and_counts() {
    // Disjointly supported columns → the Gram of any duplicate-free active
    // set is exactly diagonal (entries 7.3), so step 1 with ridge −0.5 is
    // deterministically PD; column 25 is an exact copy of column 5, so any
    // set containing both has an exactly singular Gram and `G − 0.5I` is
    // deterministically NOT PD.
    let (m, n) = (200, 40);
    let a = Mat::from_fn(m, n, |i, j| {
        let jj = if j == 25 { 5 } else { j };
        if i >= 5 * jj && i < 5 * jj + 5 {
            1.0 + 0.1 * (i - 5 * jj) as f64
        } else {
            0.0
        }
    });
    let rhs: Vec<f64> = (0..m).map(|i| ((i % 7) as f64) - 3.0).collect();
    let clean: Vec<usize> = vec![0, 2, 5, 8, 12, 16, 20, 30, 35, 39];
    let mut poisoned = clean.clone();
    poisoned.insert(7, 25); // sorted insert of the duplicate column

    let run = || {
        let mut ws = NewtonWorkspace::new();
        let mut outs = Vec::new();
        for (active, kappa) in [(&clean, -2.0), (&poisoned, -2.0), (&poisoned, 0.7)] {
            let mut d = vec![0.0; m];
            solve_newton_system_ws(
                &a, active, kappa, &rhs, &mut d, NewtonStrategy::Woodbury, 1e-12, 8, &mut ws,
            );
            outs.push(d);
        }
        (outs, ws.stats)
    };
    let (reference, stats) = shard::with_threads(1, run);
    // step 2 took the edit tier, lost PD, counted the fallback, went to CG
    assert_eq!(stats.rank1_updates, 1, "{stats:?}");
    assert_eq!(stats.downdate_fallbacks, 1, "{stats:?}");
    assert_eq!(stats.cg_fallbacks, 1, "{stats:?}");
    // step 3 recovered through the still-valid raw Gram (κ changed → re-ridge)
    assert!(stats.gram_hits >= 1, "{stats:?}");
    // the recovery solve is bitwise a cold solve of the same system
    let cold = shard::with_threads(1, || {
        let mut d = vec![0.0; m];
        solve_newton_system(
            &a, &poisoned, 0.7, &rhs, &mut d, NewtonStrategy::Woodbury, 1e-12, 8,
        );
        d
    });
    assert_eq!(bits(&cold), bits(&reference[2]), "post-fallback recovery != cold");
    // counters and recovery bits are invariant to the warm-pool budget
    for t in [2usize, 4, 8] {
        let (got, s) = shard::with_threads(t, run);
        assert_eq!(s.downdate_fallbacks, 1, "threads={t}: {s:?}");
        assert_eq!(s.cg_fallbacks, 1, "threads={t}: {s:?}");
        assert_eq!(bits(&got[2]), bits(&reference[2]), "recovery drifted at threads={t}");
    }
}

/// The tentpole end-to-end guarantee: a full SSNAL solve big enough for its
/// `Aᵀy` sweeps to fan out produces bitwise-identical solutions at every
/// within-solve thread budget.
#[test]
fn ssnal_solve_is_bitwise_invariant_to_shard_threads() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 200,
        n: 20_000,
        n0: 12,
        x_star: 5.0,
        snr: 5.0,
        seed: 77,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.4, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let opts = SsnalOptions::default();

    // the sweep plan must actually multi-shard at this shape, or the test
    // would pass vacuously
    assert!(Plan::for_work(20_000, 2 * 200).shards > 1);

    let reference = shard::with_threads(1, || ssnal_en::solver::ssnal::solve(&p, &opts));
    assert!(reference.converged);
    for t in [2usize, 4, 8] {
        let res = shard::with_threads(t, || ssnal_en::solver::ssnal::solve(&p, &opts));
        assert_eq!(res.x, reference.x, "solution drifted at shard threads={t}");
        assert_eq!(res.y, reference.y, "dual drifted at shard threads={t}");
        assert_eq!(res.iterations, reference.iterations);
        assert_eq!(res.inner_iterations, reference.inner_iterations);
    }
}

// ---- ISSUE 6: sparse (CSC) storage must reproduce dense bits -------------

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A rare-variant cohort (~6% dense) plus its densified twin.
fn sparse_cohort(m: usize, n: usize, seed: u64) -> (CscMat, Mat, Vec<f64>) {
    let cohort = generate_sparse(&SparseSnpSpec {
        base: SnpSpec { m, n_snps: n, n_causal: 8, seed, ..Default::default() },
        ..Default::default()
    });
    let DesignStorage::Sparse(sp) = cohort.a else {
        panic!("default MAF range must produce sparse storage")
    };
    let dense = sp.to_dense();
    (sp, dense, cohort.b)
}

/// CSC edge cases — an empty column, an all-dense column, single-nonzero
/// rows (first/middle/last) — through every storage-dispatched kernel,
/// bitwise against the dense loops, at single- and multi-shard plans and
/// every thread budget.
#[test]
fn csc_edge_case_columns_match_dense_bitwise() {
    let m = 9;
    let mut a = Mat::zeros(m, 5);
    // col 0: empty (all zeros)
    for i in 0..m {
        a.set(i, 1, i as f64 - 3.5); // col 1: fully dense
    }
    a.set(4, 2, 2.25); // col 2: single interior nonzero
    a.set(0, 3, -1.5); // col 3: first and last rows only
    a.set(m - 1, 3, 0.5);
    for i in (0..m).step_by(2) {
        a.set(i, 4, 1.0 + i as f64); // col 4: alternating rows
    }
    let sp = CscMat::from_dense(&a);
    assert_eq!(sp.col(0).0.len(), 0, "col 0 must be stored empty");
    assert_eq!(sp.col(1).0.len(), m, "col 1 must be stored fully dense");
    let (dr, sr) = (DesignRef::from(&a), DesignRef::from(&sp));

    let mut rng = Xoshiro256pp::seed_from_u64(6_006);
    let y = random_vec(&mut rng, m);
    let x = random_vec(&mut rng, 5);
    let idx: Vec<usize> = vec![0, 1, 2, 3, 4];

    assert_eq!(bits(&dr.t_mul_vec(&y)), bits(&sr.t_mul_vec(&y)));
    assert_eq!(bits(&dr.mul_vec(&x)), bits(&sr.mul_vec(&x)));
    let gd = dr.gram_of_cols(&idx, 0.3);
    let gs = sr.gram_of_cols(&idx, 0.3);
    assert_eq!(bits(gd.as_slice()), bits(gs.as_slice()));
    for j in 0..5 {
        assert_eq!(dr.col_dot(j, &y).to_bits(), sr.col_dot(j, &y).to_bits(), "col {j}");
        assert_eq!(dr.col_nrm2_sq(j).to_bits(), sr.col_nrm2_sq(j).to_bits(), "col {j}");
    }

    for shards in [1usize, 3, 8] {
        let plan = Plan::with_shards(shards);
        for &t in &THREADS {
            let (aty_d, aty_s, ax_d, ax_s) = shard::with_threads(t, || {
                let mut aty_d = vec![0.0; 5];
                shard::t_mul_vec_into_planned(plan, dr, &y, &mut aty_d);
                let mut aty_s = vec![0.0; 5];
                shard::t_mul_vec_into_planned(plan, sr, &y, &mut aty_s);
                let mut ax_d = vec![0.0; m];
                shard::mul_vec_support_into_planned(plan, dr, &x, &idx, &mut ax_d);
                let mut ax_s = vec![0.0; m];
                shard::mul_vec_support_into_planned(plan, sr, &x, &idx, &mut ax_s);
                (aty_d, aty_s, ax_d, ax_s)
            });
            assert_eq!(bits(&aty_d), bits(&aty_s), "Aᵀy shards={shards} threads={t}");
            assert_eq!(bits(&ax_d), bits(&ax_s), "A_J x shards={shards} threads={t}");
        }
    }
}

/// The tentpole guarantee, end to end: a full SSNAL solve on a GWAS-style
/// sparse design produces coefficients, duals and traces bitwise-identical
/// to the densified design, at every `SSNAL_THREADS` budget.
#[test]
fn sparse_fit_is_bitwise_dense_at_every_thread_budget() {
    let (sp, dense, b) = sparse_cohort(60, 4_000, 9);
    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);
    assert_eq!(
        EnetProblem::lambda_max(&sp, &b, 0.9).to_bits(),
        lmax.to_bits(),
        "λmax must not depend on storage"
    );
    let opts = SsnalOptions::default();

    let solve = |a: DesignRef<'_>| {
        let p = EnetProblem::new(a, &b, l1, l2);
        ssnal_en::solver::ssnal::solve_warm(&p, &opts, None)
    };
    let (res_ref, trace_ref) = shard::with_threads(1, || solve(DesignRef::from(&dense)));
    assert!(res_ref.converged);
    assert!(!res_ref.active_set.is_empty());
    for &t in &THREADS {
        let (res, trace) = shard::with_threads(t, || solve(DesignRef::from(&sp)));
        assert_eq!(bits(&res.x), bits(&res_ref.x), "coefficients drifted at threads={t}");
        assert_eq!(bits(&res.y), bits(&res_ref.y), "dual drifted at threads={t}");
        assert_eq!(res.active_set, res_ref.active_set);
        assert_eq!(res.iterations, res_ref.iterations);
        assert_eq!(res.inner_iterations, res_ref.inner_iterations);
        assert_eq!(
            bits(&trace.outer_residuals),
            bits(&trace_ref.outer_residuals),
            "trace residuals drifted at threads={t}"
        );
        assert_eq!(trace.inner_counts, trace_ref.inner_counts);
        assert_eq!(trace.active_sizes, trace_ref.active_sizes);
        assert_eq!(trace.final_sigma.to_bits(), trace_ref.final_sigma.to_bits());
    }
}

/// Gap-Safe screening — the augmented column norms, the scaled dual point
/// and the survivor index set — must be storage-invariant bit for bit at a
/// shape where its sweeps genuinely multi-shard.
#[test]
fn screening_survivors_match_across_storage_bitwise() {
    let (sp, dense, b) = sparse_cohort(100, 30_000, 21);
    assert!(Plan::for_work(30_000, 2 * 100).shards > 1, "sweeps must fan out");
    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.4, lmax);
    let pd = EnetProblem::new(&dense, &b, l1, l2);
    let ps = EnetProblem::new(&sp, &b, l1, l2);
    // crude reference iterate: ridge-ish shrink of the top marginal scores
    let aty = pd.a.t_mul_vec(&b);
    let x: Vec<f64> =
        aty.iter().map(|&v| if v.abs() > 0.5 * lmax { 0.1 * v } else { 0.0 }).collect();

    let aug_d = AugmentedView::new(&pd);
    let aug_s = AugmentedView::new(&ps);
    assert_eq!(bits(&aug_d.col_norms), bits(&aug_s.col_norms), "‖Ã_j‖ drifted");
    for &t in &THREADS {
        let ((dual_d, top_d, bot_d), surv_d) =
            shard::with_threads(t, || (aug_d.dual_point(&x), aug_d.gap_safe_survivors(&x)));
        let ((dual_s, top_s, bot_s), surv_s) =
            shard::with_threads(t, || (aug_s.dual_point(&x), aug_s.gap_safe_survivors(&x)));
        assert_eq!(dual_d.to_bits(), dual_s.to_bits(), "dual value drifted at threads={t}");
        assert_eq!(bits(&top_d), bits(&top_s), "θ_top drifted at threads={t}");
        assert_eq!(bits(&bot_d), bits(&bot_s), "θ_bottom drifted at threads={t}");
        assert_eq!(surv_d, surv_s, "survivor set drifted at threads={t}");
        assert!(!surv_d.is_empty(), "survivor set must be nonempty");
    }
}

/// The screened parallel λ-path — including the `gather_cols` sub-designs,
/// which must stay sparse — reproduces the dense path's bits at every
/// thread budget.
#[test]
fn screened_sparse_path_matches_dense_bitwise() {
    let (sp, dense, b) = sparse_cohort(50, 2_000, 33);
    let base = ssnal_en::path::PathOptions {
        alpha: 0.9,
        c_grid: ssnal_en::path::c_lambda_grid(0.9, 0.2, 8),
        max_active: 0,
        tol: 1e-6,
        algorithm: ssnal_en::solver::types::Algorithm::SsnalEn,
    };
    for threads in [1usize, 4] {
        let opts = ssnal_en::parallel::ParallelPathOptions {
            base: base.clone(),
            num_threads: threads,
            chunking: ssnal_en::parallel::Chunking::Chains(2),
            screening: true,
        };
        let pd = ssnal_en::parallel::solve_path_parallel(&dense, &b, &opts);
        let ps = ssnal_en::parallel::solve_path_parallel(&sp, &b, &opts);
        assert_eq!(pd.path.runs, ps.path.runs, "threads={threads}");
        for (d, s) in pd.path.points.iter().zip(ps.path.points.iter()) {
            assert_eq!(
                bits(&d.result.x),
                bits(&s.result.x),
                "path point c={} drifted (threads={threads})",
                d.c_lambda
            );
            assert_eq!(d.result.active_set, s.result.active_set);
            assert_eq!(d.result.screen_survivors, s.result.screen_survivors);
        }
    }
}

// ---- ISSUE 10: out-of-core storage must reproduce in-core bits -----------

/// Write `dense` (raw {0,1,2} dosages) as a 2-bit out-of-core file and open
/// it at the given decoded-panel cache budget. The caller removes the file
/// when done.
fn ooc_design(
    tag: &str,
    dense: &Mat,
    block_cols: usize,
    cache_bytes: usize,
) -> (OocDesign, std::path::PathBuf) {
    let path =
        std::env::temp_dir().join(format!("ssnal_ooc_lp_{}_{tag}.ooc", std::process::id()));
    ssnal_en::linalg::ooc::write_design_plink2bit(&path, DesignRef::from(dense), block_cols, 0.0)
        .unwrap();
    let d = OocDesign::open_with_cache(&path, cache_bytes).unwrap();
    (d, path)
}

/// The ISSUE 10 tentpole guarantee, end to end: a full SSNAL solve streamed
/// from an out-of-core 2-bit file produces coefficients, duals and traces
/// bitwise-identical to the in-core dense and CSC copies, at every
/// `SSNAL_THREADS` budget.
#[test]
fn ooc_fit_is_bitwise_in_core_at_every_thread_budget() {
    let (sp, dense, b) = sparse_cohort(60, 4_000, 9);
    // 8 resident panels out of 63: the solve streams with some eviction.
    let (ooc, path) = ooc_design("fit", &dense, 64, 8 * 64 * 60 * 8);
    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);
    assert_eq!(
        EnetProblem::lambda_max(&ooc, &b, 0.9).to_bits(),
        lmax.to_bits(),
        "λmax must not depend on storage"
    );
    let opts = SsnalOptions::default();

    let solve = |a: DesignRef<'_>| {
        let p = EnetProblem::new(a, &b, l1, l2);
        ssnal_en::solver::ssnal::solve_warm(&p, &opts, None)
    };
    let (res_ref, trace_ref) = shard::with_threads(1, || solve(DesignRef::from(&dense)));
    assert!(res_ref.converged);
    assert!(!res_ref.active_set.is_empty());
    for &t in &THREADS {
        for (kind, a) in [("csc", DesignRef::from(&sp)), ("ooc", DesignRef::from(&ooc))] {
            let (res, trace) = shard::with_threads(t, || solve(a));
            assert_eq!(bits(&res.x), bits(&res_ref.x), "{kind} x drifted at threads={t}");
            assert_eq!(bits(&res.y), bits(&res_ref.y), "{kind} dual drifted at threads={t}");
            assert_eq!(res.active_set, res_ref.active_set);
            assert_eq!(res.iterations, res_ref.iterations);
            assert_eq!(res.inner_iterations, res_ref.inner_iterations);
            assert_eq!(
                bits(&trace.outer_residuals),
                bits(&trace_ref.outer_residuals),
                "{kind} trace residuals drifted at threads={t}"
            );
            assert_eq!(trace.inner_counts, trace_ref.inner_counts);
            assert_eq!(trace.active_sizes, trace_ref.active_sizes);
            assert_eq!(trace.final_sigma.to_bits(), trace_ref.final_sigma.to_bits());
        }
    }
    let c = ooc.counters();
    assert!(c.cache_misses > 0 && c.bytes_read > 0, "the streamed path must actually read");
    drop(ooc);
    let _ = std::fs::remove_file(&path);
}

/// Cache-eviction-under-pressure correctness: with a budget of a single
/// decoded panel, every block access beyond the resident one evicts and
/// re-reads — the solve must still reproduce the in-core bits exactly, and
/// the resident set may never exceed the budget.
#[test]
fn ooc_fit_under_eviction_pressure_is_bitwise_in_core() {
    let (_sp, dense, b) = sparse_cohort(50, 2_000, 33);
    let panel_bytes = 64 * 50 * 8;
    let (ooc, path) = ooc_design("evict", &dense, 64, panel_bytes);
    let blocks = 2_000usize.div_ceil(64);
    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.3, lmax);
    let opts = SsnalOptions::default();

    let pd = EnetProblem::new(&dense, &b, l1, l2);
    let po = EnetProblem::new(&ooc, &b, l1, l2);
    let res_ref = shard::with_threads(1, || ssnal_en::solver::ssnal::solve(&pd, &opts));
    assert!(res_ref.converged);
    for &t in &THREADS {
        let res = shard::with_threads(t, || ssnal_en::solver::ssnal::solve(&po, &opts));
        assert_eq!(bits(&res.x), bits(&res_ref.x), "x drifted under eviction at threads={t}");
        assert_eq!(bits(&res.y), bits(&res_ref.y), "dual drifted under eviction at threads={t}");
        assert_eq!(res.active_set, res_ref.active_set);
        assert!(
            ooc.resident_bytes() <= ooc.cache_budget(),
            "resident {} exceeds budget {} at threads={t}",
            ooc.resident_bytes(),
            ooc.cache_budget()
        );
    }
    let c = ooc.counters();
    assert!(
        c.cache_misses > blocks as u64,
        "a one-panel budget must evict and re-read (misses {}, blocks {blocks})",
        c.cache_misses
    );
    drop(po);
    drop(ooc);
    let _ = std::fs::remove_file(&path);
}

/// Gap-Safe screening over the streamed tier — augmented column norms, the
/// scaled dual point, and the survivor index set — must reproduce the dense
/// bits at a shape where its sweeps genuinely multi-shard.
#[test]
fn ooc_screening_survivors_match_dense_bitwise() {
    let (_sp, dense, b) = sparse_cohort(100, 30_000, 21);
    assert!(Plan::for_work(30_000, 2 * 100).shards > 1, "sweeps must fan out");
    // 6 resident panels out of 118 blocks (block_cols 256): heavy eviction.
    let (ooc, path) = ooc_design("screen", &dense, 256, 6 * 256 * 100 * 8);
    let lmax = EnetProblem::lambda_max(&dense, &b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.4, lmax);
    let pd = EnetProblem::new(&dense, &b, l1, l2);
    let po = EnetProblem::new(&ooc, &b, l1, l2);
    let aty = pd.a.t_mul_vec(&b);
    let x: Vec<f64> =
        aty.iter().map(|&v| if v.abs() > 0.5 * lmax { 0.1 * v } else { 0.0 }).collect();

    let aug_d = AugmentedView::new(&pd);
    let aug_o = AugmentedView::new(&po);
    assert_eq!(bits(&aug_d.col_norms), bits(&aug_o.col_norms), "‖Ã_j‖ drifted");
    for &t in &THREADS {
        let ((dual_d, top_d, bot_d), surv_d) =
            shard::with_threads(t, || (aug_d.dual_point(&x), aug_d.gap_safe_survivors(&x)));
        let ((dual_o, top_o, bot_o), surv_o) =
            shard::with_threads(t, || (aug_o.dual_point(&x), aug_o.gap_safe_survivors(&x)));
        assert_eq!(dual_d.to_bits(), dual_o.to_bits(), "dual value drifted at threads={t}");
        assert_eq!(bits(&top_d), bits(&top_o), "θ_top drifted at threads={t}");
        assert_eq!(bits(&bot_d), bits(&bot_o), "θ_bottom drifted at threads={t}");
        assert_eq!(surv_d, surv_o, "survivor set drifted at threads={t}");
        assert!(!surv_d.is_empty(), "survivor set must be nonempty");
    }
    drop(aug_o);
    drop(po);
    drop(ooc);
    let _ = std::fs::remove_file(&path);
}

/// The screened parallel λ-path — whose `gather_cols` survivor sub-designs
/// materialize in-core dense for the streamed tier — reproduces the dense
/// path's bits at every thread budget.
#[test]
fn ooc_screened_path_matches_dense_bitwise() {
    let (_sp, dense, b) = sparse_cohort(50, 2_000, 33);
    let (ooc, path) = ooc_design("path", &dense, 64, 4 * 64 * 50 * 8);
    let base = ssnal_en::path::PathOptions {
        alpha: 0.9,
        c_grid: ssnal_en::path::c_lambda_grid(0.9, 0.2, 8),
        max_active: 0,
        tol: 1e-6,
        algorithm: ssnal_en::solver::types::Algorithm::SsnalEn,
    };
    for threads in [1usize, 4] {
        let opts = ssnal_en::parallel::ParallelPathOptions {
            base: base.clone(),
            num_threads: threads,
            chunking: ssnal_en::parallel::Chunking::Chains(2),
            screening: true,
        };
        let pd = ssnal_en::parallel::solve_path_parallel(&dense, &b, &opts);
        let po = ssnal_en::parallel::solve_path_parallel(&ooc, &b, &opts);
        assert_eq!(pd.path.runs, po.path.runs, "threads={threads}");
        for (d, o) in pd.path.points.iter().zip(po.path.points.iter()) {
            assert_eq!(
                bits(&d.result.x),
                bits(&o.result.x),
                "path point c={} drifted (threads={threads})",
                d.c_lambda
            );
            assert_eq!(d.result.active_set, o.result.active_set);
            assert_eq!(d.result.screen_survivors, o.result.screen_survivors);
        }
    }
    drop(ooc);
    let _ = std::fs::remove_file(&path);
}
