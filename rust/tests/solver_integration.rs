//! Cross-solver integration tests: every algorithm family must agree on the
//! optimum across problem regimes (the paper's premise: "the three methods
//! solve the same objective function and converge to the same solution").

use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::blas;
use ssnal_en::solver::types::{Algorithm, BaselineOptions, EnetProblem, SsnalOptions};
use ssnal_en::solver::{cd, duality_gap, kkt_residuals, solve_with, ssnal};

fn lambdas_for(a: &ssnal_en::linalg::Mat, b: &[f64], alpha: f64, c: f64) -> (f64, f64) {
    let lmax = EnetProblem::lambda_max(a, b, alpha);
    EnetProblem::lambdas_from_alpha(alpha, c, lmax)
}

/// One regime descriptor for the agreement matrix.
struct Regime {
    name: &'static str,
    m: usize,
    n: usize,
    n0: usize,
    alpha: f64,
    c: f64,
    snr: f64,
}

const REGIMES: &[Regime] = &[
    Regime { name: "sparse-tall", m: 80, n: 400, n0: 5, alpha: 0.9, c: 0.4, snr: 10.0 },
    Regime { name: "denser", m: 60, n: 200, n0: 30, alpha: 0.6, c: 0.2, snr: 5.0 },
    Regime { name: "lasso-like", m: 50, n: 300, n0: 8, alpha: 0.999, c: 0.5, snr: 5.0 },
    Regime { name: "ridge-heavy", m: 50, n: 150, n0: 10, alpha: 0.2, c: 0.3, snr: 5.0 },
    Regime { name: "low-snr", m: 70, n: 250, n0: 6, alpha: 0.8, c: 0.6, snr: 1.0 },
];

#[test]
fn agreement_matrix_across_regimes() {
    for (k, r) in REGIMES.iter().enumerate() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: r.m,
            n: r.n,
            n0: r.n0,
            x_star: 5.0,
            snr: r.snr,
            seed: 100 + k as u64,
        });
        let (l1, l2) = lambdas_for(&prob.a, &prob.b, r.alpha, r.c);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let reference =
            cd::solve_naive(&p, &BaselineOptions { tol: 1e-12, ..Default::default() });
        for algo in [
            Algorithm::SsnalEn,
            Algorithm::CdCovariance,
            Algorithm::CdGapSafe,
            Algorithm::Celer,
        ] {
            let res = solve_with(&p, algo, 1e-9);
            assert!(res.converged, "{}: {algo:?} did not converge", r.name);
            let dist = blas::dist2(&reference.x, &res.x);
            let scale = blas::nrm2(&reference.x) + 1.0;
            assert!(dist / scale < 1e-4, "{}: {algo:?} off by {dist}", r.name);
        }
    }
}

#[test]
fn ssnal_kkt_optimality_certificate() {
    // For each regime, SsNAL's (x, y, z=−Aᵀy) must satisfy all three KKT
    // conditions and exhibit a vanishing duality gap.
    for (k, r) in REGIMES.iter().enumerate() {
        let prob = generate_synthetic(&SyntheticSpec {
            m: r.m,
            n: r.n,
            n0: r.n0,
            x_star: 5.0,
            snr: r.snr,
            seed: 200 + k as u64,
        });
        let (l1, l2) = lambdas_for(&prob.a, &prob.b, r.alpha, r.c);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
        assert!(res.converged, "{}", r.name);
        let z: Vec<f64> = p.a.t_mul_vec(&res.y).iter().map(|v| -v).collect();
        let kkt = kkt_residuals(&p, &res.x, &res.y, &z);
        assert!(kkt.max() < 1e-6, "{}: {kkt:?}", r.name);
        if l2 > 0.0 {
            let gap = duality_gap(&p, &res.x, &res.y, &z);
            assert!(gap.abs() < 1e-5 * (1.0 + res.objective), "{}: gap {gap}", r.name);
        }
    }
}

#[test]
fn solution_is_piecewise_stable_in_lambda() {
    // tiny λ perturbations must not blow up the solution (continuity of the
    // solution path — underpins warm starting).
    let prob = generate_synthetic(&SyntheticSpec {
        m: 60,
        n: 200,
        n0: 8,
        x_star: 5.0,
        snr: 10.0,
        seed: 7,
    });
    let (l1, l2) = lambdas_for(&prob.a, &prob.b, 0.8, 0.4);
    let p1 = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let l1b = l1 * 1.001;
    let l2b = l2 * 1.001;
    let p2 = EnetProblem::new(&prob.a, &prob.b, l1b, l2b);
    let opts = SsnalOptions { tol: 1e-9, ..Default::default() };
    let r1 = ssnal::solve(&p1, &opts);
    let r2 = ssnal::solve(&p2, &opts);
    let dist = blas::dist2(&r1.x, &r2.x);
    let scale = blas::nrm2(&r1.x) + 1.0;
    assert!(dist / scale < 0.05, "solution jumped by {dist} for 0.1% λ change");
}

#[test]
fn iteration_counts_match_paper_band() {
    // Paper Tables 1–2: convergence in ≤ 6 AL iterations at tol 1e-6.
    let prob = generate_synthetic(&SyntheticSpec {
        m: 100,
        n: 2_000,
        n0: 20,
        x_star: 5.0,
        snr: 5.0,
        seed: 31,
    });
    for (alpha, max_outer) in [(0.9, 8), (0.6, 8), (0.2, 6)] {
        let (l1, l2) = lambdas_for(&prob.a, &prob.b, alpha, 0.4);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = ssnal::solve(&p, &SsnalOptions::default());
        assert!(res.converged);
        assert!(
            res.iterations <= max_outer,
            "α={alpha}: {} outer iterations (paper band ≤ {max_outer})",
            res.iterations
        );
    }
}

#[test]
fn fista_admm_reach_same_objective() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 40,
        n: 120,
        n0: 5,
        x_star: 5.0,
        snr: 8.0,
        seed: 41,
    });
    let (l1, l2) = lambdas_for(&prob.a, &prob.b, 0.75, 0.3);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let reference = solve_with(&p, Algorithm::SsnalEn, 1e-10);
    for algo in [Algorithm::Fista, Algorithm::ProximalGradient, Algorithm::Admm] {
        let res = solve_with(&p, algo, 1e-10);
        assert!(res.converged, "{algo:?}");
        assert!(
            (res.objective - reference.objective).abs() < 1e-5 * (1.0 + reference.objective),
            "{algo:?}: {} vs {}",
            res.objective,
            reference.objective
        );
    }
}

#[test]
fn active_set_grows_as_lambda_decreases() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 80,
        n: 500,
        n0: 20,
        x_star: 5.0,
        snr: 10.0,
        seed: 51,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let mut last_r = 0usize;
    for c in [0.9, 0.7, 0.5, 0.3, 0.15] {
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, c, lmax);
        let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
        let res = ssnal::solve(&p, &SsnalOptions::default());
        let r = res.active_set.len();
        assert!(r + 2 >= last_r, "active set shrank sharply: {last_r} → {r}");
        last_r = last_r.max(r);
    }
    assert!(last_r >= 20, "smallest λ should include the truth support");
}
