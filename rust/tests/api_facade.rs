//! Integration tests for the estimator facade (ISSUE 5): the
//! `Design`/`EnetModel`/`Fit` surface, the `Solver` trait registry, typed
//! error coverage, the warm-session `refit` contract (bitwise-identical to a
//! cold fit at every `SSNAL_THREADS` budget), and the `Fit` JSON-export
//! golden under `tests/fixtures/`.

use ssnal_en::api::{Design, EnetError, EnetModel};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{blas, Mat};
use ssnal_en::parallel::shard;
use ssnal_en::solver::types::Algorithm;
use ssnal_en::solver::{registry, solver_for, SolverConfig};
use ssnal_en::util::json::Json;

fn problem() -> ssnal_en::data::SyntheticProblem {
    generate_synthetic(&SyntheticSpec {
        m: 40,
        n: 120,
        n0: 5,
        x_star: 5.0,
        snr: 8.0,
        seed: 33,
    })
}

#[test]
fn facade_fit_predict_and_session_roundtrip() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let model = EnetModel::new().alpha_c(0.8, 0.3).tol(1e-8);
    let mut fit = model.fit(&design).unwrap();
    assert!(fit.result().converged);
    assert!(!fit.active_set().is_empty());
    let (l1, l2) = fit.lambdas();
    assert!(l1 > 0.0 && l2 > 0.0);
    assert!(fit.trace().is_some(), "SsNAL fits carry a trace");

    // predictions approximate the (high-SNR) response in-sample
    let preds = fit.predict(&prob.a).unwrap();
    assert_eq!(preds.len(), prob.b.len());
    let resid: f64 = preds
        .iter()
        .zip(prob.b.iter())
        .map(|(p, b)| (p - b) * (p - b))
        .sum::<f64>()
        .sqrt();
    assert!(resid < blas::nrm2(&prob.b), "fit must explain some signal");

    // shape-mismatched prediction input is a typed error
    let wrong = Mat::zeros(3, 7);
    assert!(matches!(fit.predict(&wrong), Err(EnetError::PredictShape { .. })));

    // a refit with a bad response is rejected before touching the solver
    assert!(matches!(
        fit.refit(&[1.0]),
        Err(EnetError::ShapeMismatch { .. })
    ));
}

/// The cross-solver agreement test (the paper's "all methods solve the same
/// objective" precondition), re-run at the api level through the `Solver`
/// registry: every registered algorithm must reach the same solution when
/// dispatched uniformly.
#[test]
fn registry_cross_solver_agreement() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let lmax = design.lambda_max(0.8).unwrap();
    let (l1, l2) =
        ssnal_en::solver::types::EnetProblem::lambdas_from_alpha(0.8, 0.3, lmax);
    let p = design.problem(l1, l2);
    let reference = solver_for(Algorithm::CdNaive).solve(&p, &SolverConfig::new(1e-10));

    assert_eq!(registry().len(), 8, "all eight algorithms are registered");
    for s in registry() {
        // first-order methods use a gap criterion scaled by ‖b‖², so ask
        // them for more digits; plain ISTA converges too slowly for a strict
        // agreement assert (the pre-facade test skipped it too).
        let tol = match s.algorithm() {
            Algorithm::Fista | Algorithm::Admm => 1e-10,
            _ => 1e-8,
        };
        let res = s.solve(&p, &SolverConfig::new(tol));
        assert_eq!(res.algorithm, s.algorithm(), "{} mislabels its result", s.name());
        assert!(res.objective.is_finite());
        if s.algorithm() == Algorithm::ProximalGradient {
            continue;
        }
        assert!(res.converged, "{} did not converge", s.name());
        let dist = blas::dist2(&reference.x, &res.x);
        assert!(dist < 1e-3, "{} deviates from reference by {dist}", s.name());
        assert!(
            (res.objective - reference.objective).abs() < 1e-5 * (1.0 + reference.objective),
            "{} objective mismatch",
            s.name()
        );
    }
}

/// ISSUE 5 satellite: `Fit::refit` on a warm session must be bitwise-identical
/// to a cold `fit` of the same (design, response) pair, at `SSNAL_THREADS`
/// budgets 1 and 4 — the warm workspace changes memory behavior, never bits.
#[test]
fn warm_refit_is_bitwise_identical_to_cold_fit() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 40,
        n: 150,
        n0: 5,
        x_star: 5.0,
        snr: 6.0,
        seed: 77,
    });
    let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
    for budget in [1usize, 4] {
        shard::with_threads(budget, || {
            let design = Design::new(&prob.a, &prob.b).unwrap();
            let design2 = Design::new(&prob.a, &b2).unwrap();
            let model = EnetModel::new().alpha_c(0.8, 0.35).tol(1e-8);

            let mut fit = model.fit(&design).unwrap();
            let warm = fit.refit(&b2).unwrap().clone();
            let cold = model.fit(&design2).unwrap().into_result();

            let warm_bits: Vec<u64> = warm.x.iter().map(|v| v.to_bits()).collect();
            let cold_bits: Vec<u64> = cold.x.iter().map(|v| v.to_bits()).collect();
            assert_eq!(warm_bits, cold_bits, "budget {budget}: x differs");
            assert_eq!(warm.active_set, cold.active_set, "budget {budget}");
            assert_eq!(
                warm.objective.to_bits(),
                cold.objective.to_bits(),
                "budget {budget}: objective differs"
            );
            assert_eq!(warm.iterations, cold.iterations, "budget {budget}");
            assert_eq!(warm.inner_iterations, cold.inner_iterations, "budget {budget}");

            // the session actually exercised the workspace cache
            let stats = fit.workspace_stats();
            let events = stats.factor_hits
                + stats.gram_hits
                + stats.gram_incremental
                + stats.gram_rebuilds
                + stats.direct_hits
                + stats.direct_rebuilds;
            assert!(events > 0, "budget {budget}: no workspace activity recorded");
        });
    }
}

/// ISSUE 9 satellite: `PathFit::refit_path` re-solves the λ-grid through the
/// session's warm per-chain workspaces — bitwise-identical to a fresh
/// `fit_path` at thread budgets 1 and 4, while the second pass reuses cached
/// factors instead of rebuilding them.
#[test]
fn warm_refit_path_is_bitwise_identical_to_fresh_fit_path() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 60,
        n: 400,
        n0: 6,
        x_star: 5.0,
        snr: 6.0,
        seed: 42,
    });
    for budget in [1usize, 4] {
        let design = Design::new(&prob.a, &prob.b).unwrap();
        let model = EnetModel::new()
            .alpha(0.8)
            .grid(1.0, 0.2, 12)
            .tol(1e-7)
            .threads(budget)
            .screening(true);
        let mut warm = model.fit_path(&design).unwrap();
        let first_stats = warm.workspace_stats();
        assert!(first_stats.events() > 0, "budget {budget}: no workspace activity");
        let fresh = model.fit_path(&design).unwrap();
        warm.refit_path(&design);
        assert_eq!(warm.points().len(), fresh.points().len(), "budget {budget}");
        for (k, (w, c)) in warm.points().iter().zip(fresh.points()).enumerate() {
            assert_eq!(w.c_lambda.to_bits(), c.c_lambda.to_bits(), "budget {budget} point {k}");
            let wb: Vec<u64> = w.result.x.iter().map(|v| v.to_bits()).collect();
            let cb: Vec<u64> = c.result.x.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, cb, "budget {budget} point {k}: warm path refit != fresh fit");
            assert_eq!(
                w.result.iterations, c.result.iterations,
                "budget {budget} point {k}: iteration counts differ"
            );
        }
        // the warm pass reused cached state the fresh pass had to build
        let second = warm.workspace_stats();
        assert!(
            second.factor_hits > first_stats.factor_hits,
            "budget {budget}: refit_path did not reuse cached factors \
             ({first_stats:?} → {second:?})"
        );
    }
}

/// For `(α, c_λ)` models the penalties are re-resolved against each new
/// response, exactly as a cold fit would resolve them.
#[test]
fn refit_reresolves_lambdas_from_the_new_response() {
    let prob = problem();
    let b2: Vec<f64> = prob.b.iter().map(|v| 2.0 * v).collect();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let design2 = Design::new(&prob.a, &b2).unwrap();
    let model = EnetModel::new().alpha_c(0.8, 0.3).tol(1e-8);
    let mut fit = model.fit(&design).unwrap();
    let first = fit.lambdas();
    fit.refit(&b2).unwrap();
    let cold = model.fit(&design2).unwrap();
    assert_eq!(fit.lambdas(), cold.lambdas());
    assert!(fit.lambdas().0 > first.0, "doubling b doubles λmax");
}

/// The committed JSON-export golden: stable fields must match the analytic
/// fixture (numbers to 1e-6 relative), volatile solver-dependent fields must
/// at least be present.
#[test]
fn fit_json_export_matches_golden() {
    let a = Mat::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
    let b = [3.0, -1.0];
    let design = Design::new(&a, &b).unwrap();
    let fit = EnetModel::new().lambda(0.5, 0.5).tol(1e-10).fit(&design).unwrap();
    let export = fit.to_json();
    // the export must round-trip through the crate's own parser
    let reparsed = Json::parse(&fit.export_json()).expect("export parses");

    let fixture = Json::parse(include_str!("fixtures/fit_export.json"))
        .expect("fixture parses");
    let expect = fixture.get("expect").expect("fixture has expect");
    let Json::Obj(expect_map) = expect else { panic!("expect is an object") };
    for (key, want) in expect_map {
        let got = export.get(key).unwrap_or_else(|| panic!("export missing key {key}"));
        assert_json_close(key, got, want);
        // round-tripped export agrees too
        assert_json_close(key, reparsed.get(key).expect("reparsed key"), want);
    }
    for vol in fixture.get("volatile").and_then(Json::as_arr).expect("volatile list") {
        let key = vol.as_str().expect("volatile key is a string");
        assert!(export.get(key).is_some(), "export missing volatile key {key}");
    }
}

fn assert_json_close(key: &str, got: &Json, want: &Json) {
    match (got, want) {
        (Json::Num(g), Json::Num(w)) => assert!(
            (g - w).abs() <= 1e-6 * (1.0 + w.abs()),
            "{key}: {g} vs golden {w}"
        ),
        (Json::Arr(g), Json::Arr(w)) => {
            assert_eq!(g.len(), w.len(), "{key}: length mismatch");
            for (i, (ge, we)) in g.iter().zip(w.iter()).enumerate() {
                assert_json_close(&format!("{key}[{i}]"), ge, we);
            }
        }
        (g, w) => assert_eq!(g, w, "{key} mismatch"),
    }
}

/// ISSUE 7 satellite: `Fit::predict` accepts sparse new observations, and
/// the CSC mat-vec reproduces the dense predictions bit-for-bit — a model
/// fit on any storage scores CSC held-out data without densifying it.
#[test]
fn sparse_predict_is_bitwise_identical_to_dense() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let fit = EnetModel::new().alpha_c(0.8, 0.3).tol(1e-8).fit(&design).unwrap();
    assert!(!fit.active_set().is_empty());

    let csc = ssnal_en::linalg::CscMat::from_dense(&prob.a);
    let storage = ssnal_en::linalg::DesignStorage::from(csc.clone());
    let dense_preds = fit.predict(&prob.a).unwrap();
    let sparse_preds = fit.predict(&csc).unwrap();
    let storage_preds = fit.predict(&storage).unwrap();
    for (i, ((d, s), st)) in
        dense_preds.iter().zip(&sparse_preds).zip(&storage_preds).enumerate()
    {
        assert_eq!(d.to_bits(), s.to_bits(), "row {i}: CSC predict diverges");
        assert_eq!(d.to_bits(), st.to_bits(), "row {i}: storage predict diverges");
    }

    // sparse inputs get the same typed shape check as dense ones
    let skinny = ssnal_en::linalg::CscMat::from_dense(&Mat::zeros(3, 7));
    assert!(matches!(fit.predict(&skinny), Err(EnetError::PredictShape { .. })));
}

/// ISSUE 7 satellite: `Fit::refit_many` (one fused λmax sweep for the whole
/// batch) is bitwise-identical to calling `Fit::refit` per response, and
/// leaves the session at the last response's state.
#[test]
fn refit_many_matches_sequential_refits_bitwise() {
    let prob = problem();
    let responses: Vec<Vec<f64>> = vec![
        prob.b.clone(),
        prob.b.iter().rev().copied().collect(),
        prob.b.iter().map(|v| 1.5 * v).collect(),
    ];
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let model = EnetModel::new().alpha_c(0.8, 0.35).tol(1e-8);

    let mut sequential = model.fit(&design).unwrap();
    let mut expected = Vec::new();
    for b in &responses {
        expected.push((sequential.refit(b).unwrap().clone(), sequential.lambdas()));
    }

    let mut batched = model.fit(&design).unwrap();
    let results = batched.refit_many(&responses).unwrap();
    assert_eq!(results.len(), responses.len());
    for (i, (got, (want, want_lams))) in results.iter().zip(&expected).enumerate() {
        let got_bits: Vec<u64> = got.x.iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u64> = want.x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "response {i}: x differs");
        assert_eq!(got.active_set, want.active_set, "response {i}");
        assert_eq!(
            got.objective.to_bits(),
            want.objective.to_bits(),
            "response {i}: objective differs"
        );
        assert_eq!(got.iterations, want.iterations, "response {i}");
        if i == responses.len() - 1 {
            assert_eq!(batched.lambdas(), *want_lams, "session not left at the last response");
        }
    }

    // one bad response fails the whole batch up front, with no partial solves
    let before = batched.result().x.clone();
    let mixed: Vec<Vec<f64>> = vec![prob.b.clone(), vec![1.0]];
    assert!(matches!(
        batched.refit_many(&mixed),
        Err(EnetError::ShapeMismatch { .. })
    ));
    assert_eq!(
        batched.result().x, before,
        "a rejected batch must not touch the session state"
    );
}

/// Invalid inputs reach the caller as typed errors end-to-end (the acceptance
/// criterion: no panics on bad requests).
#[test]
fn invalid_requests_are_typed_errors_not_panics() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    // negative λ
    assert!(matches!(
        EnetModel::new().lambda(-0.5, 0.1).fit(&design),
        Err(EnetError::InvalidPenalty { .. })
    ));
    // bad α
    assert!(matches!(
        EnetModel::new().alpha(-0.2).fit(&design),
        Err(EnetError::InvalidAlpha { .. })
    ));
    // shape mismatch at the design boundary
    let bad_b = vec![0.0; prob.b.len() + 1];
    assert!(matches!(
        Design::new(&prob.a, &bad_b),
        Err(EnetError::ShapeMismatch { .. })
    ));
    // non-finite data
    let mut nan_b = prob.b.clone();
    nan_b[3] = f64::NAN;
    assert!(matches!(
        Design::new(&prob.a, &nan_b),
        Err(EnetError::NonFinite { what: "response", index: 3 })
    ));
    // errors display through the crate error chain
    let e = EnetModel::new().tol(-1.0).fit(&design).unwrap_err();
    assert!(format!("{e}").contains("tolerance"));
}
