//! Integration tests for the parallel λ-path/CV engine (`ssnal_en::parallel`):
//! determinism across thread counts, bitwise agreement between the engine's
//! sequential configuration and the legacy driver, warm-start-chain active-set
//! monotonicity (property test), and parallel tuning equivalence.

use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::blas;
use ssnal_en::parallel::{solve_path_parallel, Chunking, ParallelPathOptions};
use ssnal_en::path::{c_lambda_grid, solve_path, PathOptions};
use ssnal_en::solver::types::Algorithm;
use ssnal_en::tuning::{tune_with_threads, TuningOptions};
use ssnal_en::util::quickcheck::{log_uniform_usize, run_prop, PropConfig};

fn fixed_problem(seed: u64) -> ssnal_en::data::SyntheticProblem {
    generate_synthetic(&SyntheticSpec {
        m: 60,
        n: 240,
        n0: 10,
        x_star: 5.0,
        snr: 10.0,
        seed,
    })
}

fn base_opts(points: usize) -> PathOptions {
    PathOptions {
        alpha: 0.8,
        c_grid: c_lambda_grid(0.95, 0.1, points),
        max_active: 0,
        tol: 1e-6,
        algorithm: Algorithm::SsnalEn,
    }
}

/// Determinism (ISSUE criterion): with a fixed RNG seed and fixed chunking,
/// the parallel path is bitwise-identical to the same path executed on one
/// thread — and the engine's one-chain configuration is bitwise-identical to
/// the sequential `path::solve_path` driver.
#[test]
fn parallel_path_is_deterministic_and_matches_sequential() {
    let prob = fixed_problem(2020);

    // engine (1 chain, any thread count) ≡ sequential driver, bit for bit
    let seq = solve_path(&prob.a, &prob.b, &base_opts(14));
    let one_chain = solve_path_parallel(
        &prob.a,
        &prob.b,
        &ParallelPathOptions {
            base: base_opts(14),
            num_threads: 4,
            chunking: Chunking::Chains(1),
            screening: false,
        },
    );
    assert_eq!(one_chain.path.runs, seq.runs);
    for (p, q) in one_chain.path.points.iter().zip(seq.points.iter()) {
        assert_eq!(p.result.x, q.result.x, "bitwise mismatch at c={}", p.c_lambda);
        assert_eq!(p.result.iterations, q.result.iterations);
    }

    // chunked engine: output independent of worker count (1 vs 4 vs 8)
    let chunked = |threads: usize| {
        solve_path_parallel(
            &prob.a,
            &prob.b,
            &ParallelPathOptions {
                base: base_opts(14),
                num_threads: threads,
                chunking: Chunking::Chains(4),
                screening: true,
            },
        )
    };
    let r1 = chunked(1);
    let r4 = chunked(4);
    let r8 = chunked(8);
    assert_eq!(r1.path.runs, r4.path.runs);
    assert_eq!(r1.path.runs, r8.path.runs);
    for ((p1, p4), p8) in r1
        .path
        .points
        .iter()
        .zip(r4.path.points.iter())
        .zip(r8.path.points.iter())
    {
        assert_eq!(p1.result.x, p4.result.x, "threads=1 vs 4 at c={}", p1.c_lambda);
        assert_eq!(p1.result.x, p8.result.x, "threads=1 vs 8 at c={}", p1.c_lambda);
        assert_eq!(p1.result.active_set, p4.result.active_set);
    }
}

/// Chunked chains agree with the sequential path to solver tolerance (the
/// λ2 > 0 objective is strictly convex, so both converge to the same optimum).
#[test]
fn chunked_chains_reach_the_same_optima() {
    let prob = fixed_problem(7);
    let seq = solve_path(&prob.a, &prob.b, &base_opts(12));
    let par = solve_path_parallel(
        &prob.a,
        &prob.b,
        &ParallelPathOptions {
            base: base_opts(12),
            num_threads: 0,
            chunking: Chunking::Chains(3),
            screening: true,
        },
    );
    assert_eq!(par.path.runs, seq.runs);
    for (p, q) in par.path.points.iter().zip(seq.points.iter()) {
        let dist = blas::dist2(&p.result.x, &q.result.x);
        let scale = blas::nrm2(&q.result.x) + 1.0;
        assert!(dist / scale < 1e-3, "c={}: dist {dist}", p.c_lambda);
    }
}

/// Property (ISSUE satellite): along every warm-start chain the active set
/// grows monotone-ish as c_λ decreases — small transient dips are allowed,
/// collapses are not, and the chain end must dominate the chain start.
#[test]
fn prop_active_sets_monotone_along_chains() {
    run_prop(
        PropConfig { cases: 8, seed: 0xC4A1 },
        |rng| {
            let m = log_uniform_usize(rng, 40, 70);
            let n = log_uniform_usize(rng, 150, 300);
            let n0 = log_uniform_usize(rng, 4, 12);
            let seed = rng.next_u64();
            let chains = 1 + (rng.next_u64() % 4) as usize;
            (m, n, n0, seed, chains)
        },
        |&(m, n, n0, seed, chains)| {
            let prob = generate_synthetic(&SyntheticSpec {
                m,
                n,
                n0,
                x_star: 5.0,
                snr: 10.0,
                seed,
            });
            let res = solve_path_parallel(
                &prob.a,
                &prob.b,
                &ParallelPathOptions {
                    base: PathOptions {
                        alpha: 0.8,
                        c_grid: c_lambda_grid(0.9, 0.15, 10),
                        max_active: 0,
                        tol: 1e-6,
                        algorithm: Algorithm::SsnalEn,
                    },
                    num_threads: 0,
                    chunking: Chunking::Chains(chains),
                    screening: true,
                },
            );
            for report in &res.chains {
                let seg = report.chain;
                let sizes: Vec<usize> = res.path.points[seg.start..seg.end.min(res.path.runs)]
                    .iter()
                    .map(|p| p.result.active_set.len())
                    .collect();
                if sizes.len() < 2 {
                    continue;
                }
                let mut running_max = 0usize;
                for (i, &s) in sizes.iter().enumerate() {
                    // monotone-ish: never drop far below the chain's high-water mark
                    if s + 3 < running_max {
                        return Err(format!(
                            "active set collapsed along chain {seg:?}: {sizes:?} at {i}"
                        ));
                    }
                    running_max = running_max.max(s);
                }
                if sizes.last().unwrap() + 3 < *sizes.first().unwrap() {
                    return Err(format!("chain {seg:?} shrank overall: {sizes:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Parallel tuning (criteria + K-fold CV fan-out) is bitwise-identical to the
/// sequential evaluation for every thread count.
#[test]
fn parallel_tuning_matches_sequential_bitwise() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 50,
        n: 120,
        n0: 4,
        x_star: 5.0,
        snr: 20.0,
        seed: 11,
    });
    let opts = TuningOptions {
        path: PathOptions {
            alpha: 0.9,
            c_grid: c_lambda_grid(0.9, 0.1, 10),
            max_active: 25,
            tol: 1e-6,
            algorithm: Algorithm::SsnalEn,
        },
        cv_folds: 5,
        cv_seed: 3,
    };
    let serial = tune_with_threads(&prob.a, &prob.b, &opts, 1);
    let parallel = tune_with_threads(&prob.a, &prob.b, &opts, 4);
    assert_eq!(serial.points.len(), parallel.points.len());
    assert_eq!(serial.best_gcv, parallel.best_gcv);
    assert_eq!(serial.best_ebic, parallel.best_ebic);
    assert_eq!(serial.best_cv, parallel.best_cv);
    for (s, p) in serial.points.iter().zip(parallel.points.iter()) {
        assert_eq!(s.gcv, p.gcv, "gcv at c={}", s.c_lambda);
        assert_eq!(s.ebic, p.ebic);
        assert_eq!(s.rss, p.rss);
        assert_eq!(s.dof, p.dof);
        assert_eq!(s.cv, p.cv);
    }
}

/// Truncation coordination: with a max-active cap and many chains, the
/// assembled path ends at the first cap hit and wasted tail work is pruned.
#[test]
fn truncation_is_coordinated_across_chains() {
    let prob = fixed_problem(5);
    let mut base = base_opts(36);
    base.c_grid = c_lambda_grid(0.95, 0.04, 36);
    base.max_active = 10;
    let res = solve_path_parallel(
        &prob.a,
        &prob.b,
        &ParallelPathOptions {
            base,
            num_threads: 4,
            chunking: Chunking::Chains(6),
            screening: false,
        },
    );
    assert!(res.path.truncated);
    assert!(res.path.runs < 36);
    let sizes: Vec<usize> =
        res.path.points.iter().map(|p| p.result.active_set.len()).collect();
    assert!(*sizes.last().unwrap() >= 10, "{sizes:?}");
    for &s in &sizes[..sizes.len() - 1] {
        assert!(s < 10, "only the final point may hit the cap: {sizes:?}");
    }
}

/// ISSUE 2 satellite: a deliberately imbalanced λ-grid — the low-c tail
/// chains carry several times the work of the head chains, so a static
/// chain→worker assignment would leave one worker with >2× the load — must
/// produce output identical to the static split at every worker count. The
/// work-stealing deques only reassign *which worker* runs a chain, never the
/// chain's numbers.
#[test]
fn work_stealing_on_imbalanced_grid_matches_static_split() {
    let prob = fixed_problem(99);
    let mut base = base_opts(24);
    base.c_grid = c_lambda_grid(0.9, 0.05, 24);
    let run = |threads: usize| {
        solve_path_parallel(
            &prob.a,
            &prob.b,
            &ParallelPathOptions {
                base: base.clone(),
                num_threads: threads,
                chunking: Chunking::Chains(8),
                screening: false,
            },
        )
    };
    let reference = run(1);
    assert_eq!(reference.path.runs, 24, "no truncation expected");

    // The grid really is imbalanced: per-chain cost proxy (active-set sizes
    // driving the O(r²m) Newton systems, plus SsN steps) spreads ≥ 2×.
    let costs: Vec<usize> = reference
        .chains
        .iter()
        .map(|report| {
            let seg = report.chain;
            reference.path.points[seg.start..seg.end]
                .iter()
                .map(|p| p.result.active_set.len() + p.result.inner_iterations)
                .sum()
        })
        .collect();
    let mn = *costs.iter().min().unwrap();
    let mx = *costs.iter().max().unwrap();
    assert!(
        mx >= 2 * (mn + 1),
        "grid not imbalanced enough for the test to bite: {costs:?}"
    );

    for threads in [2usize, 3, 8] {
        let got = run(threads);
        assert_eq!(got.path.runs, reference.path.runs, "threads={threads}");
        for (p, q) in got.path.points.iter().zip(reference.path.points.iter()) {
            assert_eq!(p.result.x, q.result.x, "threads={threads} c={}", p.c_lambda);
            assert_eq!(p.result.active_set, q.result.active_set);
            assert_eq!(p.result.objective.to_bits(), q.result.objective.to_bits());
        }
    }
}
