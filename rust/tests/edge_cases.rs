//! Edge-case and failure-injection tests: degenerate shapes, extreme
//! penalties, pathological data. A production solver must degrade gracefully,
//! not panic or silently mis-converge.

use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{blas, Mat};
use ssnal_en::solver::types::{Algorithm, BaselineOptions, EnetProblem, SsnalOptions};
use ssnal_en::solver::{cd, primal_objective, solve_with, ssnal};

#[test]
fn single_observation() {
    let a = Mat::from_row_major(1, 5, &[1.0, -2.0, 0.5, 3.0, -1.0]);
    let b = [2.0];
    let p = EnetProblem::new(&a, &b, 0.5, 0.5);
    let res = ssnal::solve(&p, &SsnalOptions::default());
    assert!(res.converged);
    let cdres = cd::solve_naive(&p, &BaselineOptions { tol: 1e-10, ..Default::default() });
    assert!(blas::dist2(&res.x, &cdres.x) < 1e-5);
}

#[test]
fn single_feature() {
    let a = Mat::from_fn(20, 1, |i, _| (i as f64 * 0.37).sin() + 1.0);
    let b: Vec<f64> = (0..20).map(|i| 2.0 * ((i as f64 * 0.37).sin() + 1.0) + 0.01).collect();
    let p = EnetProblem::new(&a, &b, 0.1, 0.1);
    let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-9, ..Default::default() });
    assert!(res.converged);
    // closed form for 1 feature: x = soft(aᵀb, λ1)/(‖a‖² + λ2)
    let atb = blas::dot(a.col(0), &b);
    let closed = ssnal_en::prox::soft_threshold(atb, 0.1) / (blas::nrm2_sq(a.col(0)) + 0.1);
    assert!((res.x[0] - closed).abs() < 1e-6, "{} vs {closed}", res.x[0]);
}

#[test]
fn zero_response_gives_zero_solution() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 30,
        n: 100,
        n0: 0,
        x_star: 0.0,
        snr: 5.0,
        seed: 1,
    });
    let zeros = vec![0.0; 30];
    let p = EnetProblem::new(&prob.a, &zeros, 0.5, 0.5);
    let res = ssnal::solve(&p, &SsnalOptions::default());
    assert!(res.converged);
    assert!(res.x.iter().all(|&v| v == 0.0));
    assert_eq!(res.objective, 0.0);
}

#[test]
fn huge_penalties_do_not_overflow() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 20,
        n: 50,
        n0: 5,
        x_star: 5.0,
        snr: 5.0,
        seed: 2,
    });
    let p = EnetProblem::new(&prob.a, &prob.b, 1e12, 1e12);
    let res = ssnal::solve(&p, &SsnalOptions::default());
    assert!(res.converged);
    assert_eq!(res.active_set.len(), 0);
    assert!(res.objective.is_finite());
}

#[test]
fn tiny_penalties_approach_least_squares() {
    // n < m, tiny penalties ⇒ close to OLS
    let prob = generate_synthetic(&SyntheticSpec {
        m: 100,
        n: 10,
        n0: 5,
        x_star: 2.0,
        snr: 50.0,
        seed: 3,
    });
    let p = EnetProblem::new(&prob.a, &prob.b, 1e-8, 1e-8);
    let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-10, ..Default::default() });
    assert!(res.converged);
    let idx: Vec<usize> = (0..10).collect();
    let ols = ssnal_en::linalg::lstsq::ridge_on_support(&prob.a, &idx, &prob.b, 0.0);
    for j in 0..10 {
        assert!((res.x[j] - ols[j]).abs() < 1e-4, "j={j}");
    }
}

#[test]
fn duplicate_columns_split_weight_with_ridge() {
    // the Elastic Net's signature behaviour (Zou & Hastie 2005): identical
    // features receive identical coefficients when λ2 > 0.
    let m = 40;
    let mut rng = ssnal_en::rng::Xoshiro256pp::seed_from_u64(4);
    let col: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
    let mut a = Mat::zeros(m, 3);
    a.col_mut(0).copy_from_slice(&col);
    a.col_mut(1).copy_from_slice(&col);
    for i in 0..m {
        a.set(i, 2, rng.next_gaussian());
    }
    let b: Vec<f64> = (0..m).map(|i| 3.0 * col[i] + 0.05 * rng.next_gaussian()).collect();
    let p = EnetProblem::new(&a, &b, 0.1, 1.0);
    let res = ssnal::solve(&p, &SsnalOptions { tol: 1e-10, ..Default::default() });
    assert!(res.converged);
    assert!(
        (res.x[0] - res.x[1]).abs() < 1e-6,
        "duplicate columns got {} vs {}",
        res.x[0],
        res.x[1]
    );
    assert!(res.x[0] > 0.5, "signal shared across duplicates");
}

#[test]
fn wide_and_short_extreme_aspect() {
    // m=3, n=2000 — the ultra-high-dimensional extreme
    let prob = generate_synthetic(&SyntheticSpec {
        m: 3,
        n: 2000,
        n0: 1,
        x_star: 5.0,
        snr: 100.0,
        seed: 5,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.9);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.5, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let res = ssnal::solve(&p, &SsnalOptions::default());
    assert!(res.converged);
    assert!(res.active_set.len() <= 3, "at most m features can be 'needed'");
}

#[test]
fn all_algorithms_handle_constant_zero_columns() {
    let mut a = Mat::from_fn(25, 40, |i, j| ((i * 7 + j * 3) as f64 * 0.13).sin());
    for j in [5usize, 17, 33] {
        for i in 0..25 {
            a.set(i, j, 0.0);
        }
    }
    let b: Vec<f64> = (0..25).map(|i| (i as f64 * 0.29).cos()).collect();
    let p = EnetProblem::new(&a, &b, 0.05, 0.05);
    for algo in [
        Algorithm::SsnalEn,
        Algorithm::CdNaive,
        Algorithm::CdCovariance,
        Algorithm::Fista,
        Algorithm::Admm,
        Algorithm::CdGapSafe,
        Algorithm::Celer,
    ] {
        let res = solve_with(&p, algo, 1e-7);
        assert!(res.converged, "{algo:?}");
        for j in [5usize, 17, 33] {
            assert_eq!(res.x[j], 0.0, "{algo:?} put weight on a dead column");
        }
    }
}

#[test]
fn max_iterations_reported_honestly() {
    let prob = generate_synthetic(&SyntheticSpec {
        m: 40,
        n: 200,
        n0: 10,
        x_star: 5.0,
        snr: 5.0,
        seed: 6,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.2, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let res = ssnal::solve(
        &p,
        &SsnalOptions { tol: 1e-14, max_outer: 2, ..Default::default() },
    );
    // cannot hit 1e-14 in 2 outer iterations from cold
    assert!(!res.converged, "must not claim convergence it didn't achieve");
    assert_eq!(res.iterations, 2);
}

#[test]
fn objective_decreases_monotonically_along_al_iterations() {
    // AL multiplier iterates x^k must drive the primal objective down
    // (not strictly guaranteed per-iteration in general, but holds on these
    // well-conditioned instances and guards against sign errors).
    let prob = generate_synthetic(&SyntheticSpec {
        m: 50,
        n: 300,
        n0: 8,
        x_star: 5.0,
        snr: 10.0,
        seed: 7,
    });
    let lmax = EnetProblem::lambda_max(&prob.a, &prob.b, 0.8);
    let (l1, l2) = EnetProblem::lambdas_from_alpha(0.8, 0.4, lmax);
    let p = EnetProblem::new(&prob.a, &prob.b, l1, l2);
    let zero_obj = primal_objective(&p, &vec![0.0; 300]);
    let res = ssnal::solve(&p, &SsnalOptions::default());
    assert!(res.objective <= zero_obj, "final objective above the zero point");
}

#[test]
fn nan_input_is_caught_not_propagated_silently() {
    let mut a = Mat::from_fn(10, 20, |i, j| ((i + j) as f64 * 0.21).sin());
    a.set(3, 7, f64::NAN);
    let b: Vec<f64> = (0..10).map(|i| i as f64 * 0.1).collect();
    let p = EnetProblem::new(&a, &b, 0.1, 0.1);
    let res = ssnal::solve(&p, &SsnalOptions { max_outer: 5, ..Default::default() });
    // acceptable outcomes: non-convergence, or NaN surfaced in the residual —
    // but never a "converged" flag with a poisoned solution
    if res.converged {
        assert!(
            res.x.iter().all(|v| v.is_finite()),
            "claimed convergence with non-finite solution"
        );
    }
}
