//! Integration tests for the `ssnal-en serve` front end: server responses
//! byte-identical to the direct `api::` calls they wrap, sparse CSC designs
//! round-tripping fit→predict without densification, malformed requests
//! answered with 4xx statuses (never a panic, never a wedged server),
//! concurrency at several client counts leaving response bytes unchanged,
//! and LRU session eviction staying invisible to correctness.
//!
//! The serving-hardening layer is pinned here too: a full admission queue
//! answers `503` with `Retry-After`, a request whose budget expires in the
//! queue answers `503` without reaching the solver, stalled partial requests
//! answer `408` (idle keep-alive connections close silently), graceful drain
//! finishes in-flight work while refusing late connects — programmatically
//! and via SIGTERM against the real binary — and concurrent single-`b`
//! refits coalesce into `refit_many` batches without changing a response
//! byte, observable through `GET /v1/stats`.

use ssnal_en::api::{Design, EnetModel};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::linalg::{CscMat, Mat};
use ssnal_en::serve::{http_request, Client, Server, ServerConfig};
use ssnal_en::util::json::Json;

const TOL: f64 = 1e-6;

fn problem() -> ssnal_en::data::SyntheticProblem {
    generate_synthetic(&SyntheticSpec {
        m: 30,
        n: 200,
        n0: 4,
        x_star: 5.0,
        snr: 6.0,
        seed: 91,
    })
}

/// Spawn a server on an ephemeral port with the given session cap, solver
/// thread budget, and body cap.
fn spawn_server(sessions: usize, threads: usize, max_body: usize) -> ssnal_en::serve::ServerHandle {
    let cfg = ServerConfig {
        port: 0,
        sessions,
        threads,
        max_body,
        ..ServerConfig::default()
    };
    Server::bind(cfg).expect("bind ephemeral port").spawn().expect("spawn server")
}

/// Row-major dense matrix spec for a column-major `Mat`, built through
/// `Json` so every f64 round-trips bit-exactly over the wire.
fn dense_spec(a: &Mat) -> Vec<(&'static str, Json)> {
    let (m, n) = (a.rows(), a.cols());
    let mut values = Vec::with_capacity(m * n);
    for i in 0..m {
        for j in 0..n {
            values.push(Json::Num(a.col(j)[i]));
        }
    }
    vec![
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("dense", Json::Arr(values)),
    ]
}

fn num_arr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

/// Register a dense design, returning its `design_id`.
fn register_dense(client: &mut Client, a: &Mat, b: &[f64]) -> String {
    let mut fields = dense_spec(a);
    fields.push(("b", num_arr(b)));
    let (status, body) =
        client.request("POST", "/v1/designs", &Json::obj(fields).to_string()).expect("register");
    assert_eq!(status, 200, "registration failed: {body}");
    Json::parse(&body)
        .expect("registration response parses")
        .get("design_id")
        .and_then(|v| v.as_str().map(String::from))
        .expect("design_id present")
}

fn model_spec(c: f64) -> Json {
    Json::obj(vec![("c", Json::Num(c)), ("tol", Json::Num(TOL))])
}

fn fit_body(design_id: &str, c: f64) -> String {
    Json::obj(vec![("design_id", Json::Str(design_id.to_string())), ("model", model_spec(c))])
        .to_string()
}

fn refit_body(design_id: &str, c: f64, b: &[f64]) -> String {
    Json::obj(vec![
        ("design_id", Json::Str(design_id.to_string())),
        ("model", model_spec(c)),
        ("b", num_arr(b)),
    ])
    .to_string()
}

/// Exact-bit comparison of a parsed JSON number array against reference
/// values (`Json` round-trips f64 exactly, so this is a bitwise check of the
/// wire payload).
fn assert_num_arr_bits(got: &Json, want: &[f64], what: &str) {
    let arr = got.as_arr().unwrap_or_else(|| panic!("{what} is an array"));
    assert_eq!(arr.len(), want.len(), "{what}: length");
    for (i, (g, w)) in arr.iter().zip(want).enumerate() {
        let g = g.as_f64().unwrap_or_else(|| panic!("{what}[{i}] is a number"));
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

/// The headline acceptance criterion: `/v1/fit`, `/v1/refit` (single and
/// batch), `/v1/predict`, and `/v1/path` return exactly the bytes (or bits)
/// the equivalent direct `api::` calls produce.
#[test]
fn server_responses_match_direct_api_bitwise() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let model = EnetModel::new().alpha_c(0.8, 0.5).tol(TOL);
    let mut reference = model.fit(&design).unwrap();
    let expected_fit = reference.export_json();

    let handle = spawn_server(16, 0, 256 << 20);
    let mut client = Client::connect(&handle.addr()).unwrap();
    let id = register_dense(&mut client, &prob.a, &prob.b);

    // fit on the stored response == direct Fit::export_json
    let (status, body) = client.request("POST", "/v1/fit", &fit_body(&id, 0.5)).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, expected_fit, "server fit diverges from direct api");

    // a repeat fit is served from the cached solve — same bytes again
    let (status, body) = client.request("POST", "/v1/fit", &fit_body(&id, 0.5)).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, expected_fit, "cached fit diverges");

    // single refit == direct Fit::refit
    let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
    reference.refit(&b2).unwrap();
    let expected_refit = reference.export_json();
    let (status, body) = client.request("POST", "/v1/refit", &refit_body(&id, 0.5, &b2)).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, expected_refit, "server refit diverges from direct api");

    // batch refit == the same solves run sequentially through Fit::refit
    let b3: Vec<f64> = prob.b.iter().map(|v| 1.5 * v).collect();
    let mut expected_batch = Vec::new();
    for b in [&prob.b, &b3] {
        reference.refit(b).unwrap();
        expected_batch.push(reference.export_json());
    }
    let batch = Json::obj(vec![
        ("design_id", Json::Str(id.clone())),
        ("model", model_spec(0.5)),
        ("bs", Json::Arr(vec![num_arr(&prob.b), num_arr(&b3)])),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/refit", &batch).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = Json::parse(&body).expect("batch response parses");
    assert_eq!(parsed.get("count").and_then(Json::as_usize), Some(2));
    let fits = parsed.get("fits").and_then(Json::as_arr).expect("fits array");
    for (got, want) in fits.iter().zip(&expected_batch) {
        // Json::Obj is a BTreeMap, so re-rendering the parsed object
        // reproduces the exact original bytes.
        assert_eq!(&got.to_string(), want, "batch element diverges from sequential refit");
    }

    // predict == direct Fit::predict (bit-for-bit through the JSON numbers);
    // both sessions sit at the batch's last solve, so the coefficients agree
    let expected_preds = reference.predict(&prob.a).unwrap();
    let pred_req = Json::obj(vec![
        ("design_id", Json::Str(id.clone())),
        ("model", model_spec(0.5)),
        ("a_new", Json::obj(dense_spec(&prob.a))),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/predict", &pred_req).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = Json::parse(&body).expect("predictions parse");
    assert_num_arr_bits(
        parsed.get("predictions").expect("predictions field"),
        &expected_preds,
        "predictions",
    );

    // path == direct EnetModel::fit_path over the same grid
    let path_model = Json::obj(vec![
        ("alpha", Json::Num(0.8)),
        ("tol", Json::Num(TOL)),
        (
            "grid",
            Json::obj(vec![
                ("hi", Json::Num(0.9)),
                ("lo", Json::Num(0.2)),
                ("points", Json::Num(4.0)),
            ]),
        ),
    ]);
    let path_req = Json::obj(vec![
        ("design_id", Json::Str(id.clone())),
        ("model", path_model),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/path", &path_req).unwrap();
    assert_eq!(status, 200, "{body}");
    let direct = EnetModel::new()
        .alpha(0.8)
        .tol(TOL)
        .grid(0.9, 0.2, 4)
        .fit_path(&design)
        .unwrap();
    let parsed = Json::parse(&body).expect("path response parses");
    assert_eq!(
        parsed.get("lambda_max").and_then(Json::as_f64).map(f64::to_bits),
        Some(direct.lambda_max().to_bits()),
        "lambda_max diverges"
    );
    assert_eq!(parsed.get("runs").and_then(Json::as_usize), Some(direct.runs()));
    let points = parsed.get("points").and_then(Json::as_arr).expect("points array");
    assert_eq!(points.len(), direct.points().len());
    for (got, want) in points.iter().zip(direct.points()) {
        assert_eq!(
            got.get("objective").and_then(Json::as_f64).map(f64::to_bits),
            Some(want.result.objective.to_bits()),
            "path objective diverges"
        );
        let coefs: Vec<f64> =
            want.result.active_set.iter().map(|&j| want.result.x[j]).collect();
        assert_num_arr_bits(got.get("coefficients").expect("coefficients"), &coefs, "path coefs");
    }

    handle.stop();
}

/// Sparse acceptance criterion: a CSC design registered over the wire fits
/// and predicts through the server with bytes identical to the dense direct
/// api on the same values — no densification anywhere in the round trip.
#[test]
fn sparse_design_roundtrips_fit_and_predict() {
    let (m, n) = (24, 80);
    let a = Mat::from_fn(m, n, |i, j| {
        if (i + 2 * j) % 7 == 0 {
            (i + 1) as f64 * 0.3 - (j % 5) as f64 * 0.7
        } else {
            0.0
        }
    });
    let b: Vec<f64> = (0..m).map(|i| ((i * i % 11) as f64) - 5.0).collect();
    let csc = CscMat::from_dense(&a);

    // direct dense reference — the sparse kernels' contract is to reproduce
    // these bits exactly
    let design = Design::new(&a, &b).unwrap();
    let fit = EnetModel::new().alpha_c(0.8, 0.4).tol(TOL).fit(&design).unwrap();
    let expected_fit = fit.export_json();
    let expected_preds = fit.predict(&csc).unwrap();

    let csc_spec = |mat: &CscMat| -> Vec<(&'static str, Json)> {
        vec![
            ("m", Json::Num(mat.rows() as f64)),
            ("n", Json::Num(mat.cols() as f64)),
            ("col_ptr", Json::Arr(mat.col_ptr().iter().map(|&p| Json::Num(p as f64)).collect())),
            ("row_idx", Json::Arr(mat.row_idx().iter().map(|&i| Json::Num(i as f64)).collect())),
            ("values", num_arr(mat.values())),
        ]
    };

    let handle = spawn_server(16, 0, 256 << 20);
    let mut client = Client::connect(&handle.addr()).unwrap();
    let mut fields = csc_spec(&csc);
    fields.push(("b", num_arr(&b)));
    let (status, body) =
        client.request("POST", "/v1/designs", &Json::obj(fields).to_string()).unwrap();
    assert_eq!(status, 200, "{body}");
    let reg = Json::parse(&body).expect("registration parses");
    assert_eq!(reg.get("sparse"), Some(&Json::Bool(true)), "stored as CSC: {body}");
    let id = reg.get("design_id").and_then(|v| v.as_str().map(String::from)).unwrap();

    let (status, body) = client.request("POST", "/v1/fit", &fit_body(&id, 0.4)).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, expected_fit, "sparse server fit diverges from dense direct api");

    // predict with a sparse a_new spec (the design itself)
    let pred_req = Json::obj(vec![
        ("design_id", Json::Str(id)),
        ("model", model_spec(0.4)),
        ("a_new", Json::obj(csc_spec(&csc))),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/predict", &pred_req).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = Json::parse(&body).expect("predictions parse");
    assert_num_arr_bits(
        parsed.get("predictions").expect("predictions field"),
        &expected_preds,
        "sparse predictions",
    );

    handle.stop();
}

/// Every malformed request maps to a 4xx with a JSON error body — no panic,
/// and the server keeps answering afterwards (health stays 200).
#[test]
fn malformed_requests_get_4xx_and_never_wedge_the_server() {
    let a = Mat::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
    let b = [3.0, -1.0];
    let handle = spawn_server(16, 0, 2048);
    let addr = handle.addr();
    let mut client = Client::connect(&addr).unwrap();
    let id = register_dense(&mut client, &a, &b);

    let post = |path: &str, body: &str| http_request(&addr, "POST", path, body).unwrap();

    // transport- and routing-level defects
    assert_eq!(post("/v1/fit", "{not json").0, 400, "bad JSON");
    assert_eq!(post("/v1/nope", "{}").0, 404, "unknown route");
    assert_eq!(http_request(&addr, "GET", "/v1/fit", "").unwrap().0, 405, "wrong method");

    // registration defects
    let short_dense = Json::obj(vec![
        ("m", Json::Num(2.0)),
        ("n", Json::Num(2.0)),
        ("dense", num_arr(&[1.0, 2.0, 3.0])),
        ("b", num_arr(&b)),
    ])
    .to_string();
    assert_eq!(post("/v1/designs", &short_dense).0, 400, "wrong dense length");
    let bad_csc = Json::obj(vec![
        ("m", Json::Num(2.0)),
        ("n", Json::Num(2.0)),
        ("col_ptr", num_arr(&[0.0, 1.0])), // wrong length: needs n+1 entries
        ("row_idx", num_arr(&[0.0])),
        ("values", num_arr(&[1.0])),
        ("b", num_arr(&b)),
    ])
    .to_string();
    let (status, body) = post("/v1/designs", &bad_csc);
    assert_eq!(status, 400, "defective CSC structure: {body}");
    assert_eq!(post("/v1/designs", "{}").0, 400, "missing matrix payload");

    // lookup and field defects
    assert_eq!(post("/v1/fit", "{}").0, 400, "missing design_id");
    assert_eq!(post("/v1/fit", r#"{"design_id":"d0000000000000000"}"#).0, 404, "unknown design");
    let wrong_b = refit_body(&id, 0.5, &[1.0, 2.0, 3.0]);
    assert_eq!(post("/v1/refit", &wrong_b).0, 400, "shape-mismatched response");
    let both = Json::obj(vec![
        ("design_id", Json::Str(id.clone())),
        ("b", num_arr(&b)),
        ("bs", Json::Arr(vec![num_arr(&b)])),
    ])
    .to_string();
    assert_eq!(post("/v1/refit", &both).0, 400, "b and bs together");

    // model-spec defects
    let model_req = |model: Json| {
        Json::obj(vec![("design_id", Json::Str(id.clone())), ("model", model)]).to_string()
    };
    let unknown = model_req(Json::obj(vec![("ridge", Json::Num(1.0))]));
    assert_eq!(post("/v1/fit", &unknown).0, 400, "unknown model field");
    let threads = model_req(Json::obj(vec![("threads", Json::Num(4.0))]));
    assert_eq!(post("/v1/fit", &threads).0, 400, "client-set threads rejected");
    let bad_algo = model_req(Json::obj(vec![("algorithm", Json::Str("lars".to_string()))]));
    assert_eq!(post("/v1/fit", &bad_algo).0, 400, "unknown algorithm");
    let conflict = model_req(Json::obj(vec![
        ("alpha", Json::Num(0.8)),
        ("lam1", Json::Num(0.5)),
        ("lam2", Json::Num(0.5)),
    ]));
    assert_eq!(post("/v1/fit", &conflict).0, 400, "alpha with explicit lambdas");
    let negative = model_req(Json::obj(vec![("lam1", Json::Num(-0.5)), ("lam2", Json::Num(0.5))]));
    assert_eq!(post("/v1/fit", &negative).0, 400, "negative penalty");

    // oversized declared body: rejected before a body byte is read
    let mut raw = Client::connect(&addr).unwrap();
    let head = b"POST /v1/fit HTTP/1.1\r\nhost: t\r\ncontent-length: 4096\r\n\r\n";
    let (status, _) = raw.request_raw(head).unwrap();
    assert_eq!(status, 413, "body over the cap");

    // garbage request line
    let mut raw = Client::connect(&addr).unwrap();
    let (status, _) = raw.request_raw(b"BLARG\r\n\r\n").unwrap();
    assert_eq!(status, 400, "malformed request line");

    // after all of the above the server still answers correctly
    let (status, body) = http_request(&addr, "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200, "{body}");
    let health = Json::parse(&body).expect("health parses");
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    let (status, body) = client.request("POST", "/v1/fit", &fit_body(&id, 0.5)).unwrap();
    assert_eq!(status, 200, "fit after the error barrage: {body}");

    handle.stop();
}

/// Concurrency and thread budget change latency only: at 1, 8, and 64
/// concurrent clients, against servers budgeted at 1 and at 4 solver
/// threads, every response is byte-identical to the sequential direct call.
#[test]
fn concurrent_clients_are_bitwise_identical_to_sequential() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let mut reference = EnetModel::new().alpha_c(0.8, 0.5).tol(TOL).fit(&design).unwrap();
    let m = prob.b.len();
    let response = |i: usize| -> Vec<f64> { (0..m).map(|k| prob.b[(k + i) % m]).collect() };
    let max_clients = 64;
    let mut expected = Vec::with_capacity(max_clients);
    for i in 0..max_clients {
        reference.refit(&response(i)).unwrap();
        expected.push(reference.export_json());
    }

    for budget in [1usize, 4] {
        let handle = spawn_server(16, budget, 256 << 20);
        let addr = handle.addr();
        let mut setup = Client::connect(&addr).unwrap();
        let id = register_dense(&mut setup, &prob.a, &prob.b);
        for clients in [1usize, 8, 64] {
            let expected = &expected;
            let addr = &addr;
            let id = &id;
            let response = &response;
            std::thread::scope(|scope| {
                let workers: Vec<_> = (0..clients)
                    .map(|c| {
                        scope.spawn(move || {
                            let mut client = Client::connect(addr).expect("connect");
                            let body = refit_body(id, 0.5, &response(c));
                            let (status, got) =
                                client.request("POST", "/v1/refit", &body).expect("refit");
                            assert_eq!(status, 200, "budget {budget}: {got}");
                            assert_eq!(
                                got, expected[c],
                                "budget {budget}, {clients} clients: response {c} diverges"
                            );
                        })
                    })
                    .collect();
                for w in workers {
                    w.join().expect("client thread");
                }
            });
        }
        handle.stop();
    }
}

/// LRU eviction under a tiny session cap: sessions churn while another model
/// spec is being hammered concurrently, yet every response stays bitwise
/// equal to the direct api and the resident count respects the cap.
#[test]
fn lru_eviction_does_not_corrupt_warm_sessions() {
    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let b2: Vec<f64> = prob.b.iter().rev().copied().collect();
    let mut reference = EnetModel::new().alpha_c(0.8, 0.5).tol(TOL).fit(&design).unwrap();
    reference.refit(&b2).unwrap();
    let expected_a = reference.export_json();

    let handle = spawn_server(2, 0, 256 << 20);
    let addr = handle.addr();
    let mut setup = Client::connect(&addr).unwrap();
    let id = register_dense(&mut setup, &prob.a, &prob.b);

    // model A stays under continuous refit load while fresh model specs
    // (distinct c values → distinct session keys) churn the 2-slot LRU
    std::thread::scope(|scope| {
        let addr = &addr;
        let id = &id;
        let expected_a = &expected_a;
        let b2 = &b2;
        let hammer = scope.spawn(move || {
            let mut client = Client::connect(addr).expect("connect");
            for round in 0..6 {
                let body = refit_body(id, 0.5, b2);
                let (status, got) = client.request("POST", "/v1/refit", &body).expect("refit");
                assert_eq!(status, 200, "round {round}: {got}");
                assert_eq!(got, *expected_a, "round {round}: eviction churn changed the bytes");
            }
        });
        let mut churn = Client::connect(addr).expect("connect");
        for k in 0..5 {
            let c = 0.3 + 0.05 * k as f64;
            let (status, got) = churn.request("POST", "/v1/fit", &fit_body(id, c)).expect("fit");
            assert_eq!(status, 200, "churn fit {k}: {got}");
        }
        hammer.join().expect("hammer thread");
    });

    // the cap held, and the evicted-then-recreated model A still solves to
    // the exact same bytes
    let (status, body) = http_request(&addr, "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200);
    let sessions = Json::parse(&body)
        .expect("health parses")
        .get("sessions")
        .and_then(Json::as_usize)
        .expect("sessions counter");
    assert!(sessions <= 2, "LRU cap violated: {sessions} resident sessions");
    let (status, got) = setup.request("POST", "/v1/refit", &refit_body(&id, 0.5, &b2)).unwrap();
    assert_eq!(status, 200, "{got}");
    assert_eq!(got, expected_a, "recreated session diverges from direct api");

    handle.stop();
}

/// A λ-path request heavy enough (multi-point grid, tight tolerance, debug
/// build) to hold an execution slot while probe requests observe the
/// admission behavior around it.
fn heavy_path_body(design_id: &str) -> String {
    let model = Json::obj(vec![
        ("alpha", Json::Num(0.8)),
        ("tol", Json::Num(1e-9)),
        (
            "grid",
            Json::obj(vec![
                ("hi", Json::Num(0.9)),
                ("lo", Json::Num(0.02)),
                ("points", Json::Num(16.0)),
            ]),
        ),
    ]);
    Json::obj(vec![("design_id", Json::Str(design_id.to_string())), ("model", model)]).to_string()
}

/// With a single execution slot and no queue in front of it, a request that
/// arrives while the slot is held is rejected `503` with `Retry-After` — and
/// the in-flight request still completes normally.
#[test]
fn full_admission_queue_answers_503_with_retry_after() {
    let prob = problem();
    let cfg = ServerConfig {
        port: 0,
        max_inflight: 1,
        queue_depth: 0,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).expect("bind").spawn().expect("spawn");
    let addr = handle.addr();
    let mut setup = Client::connect(&addr).unwrap();
    let id = register_dense(&mut setup, &prob.a, &prob.b);

    let mut rejection = None;
    for round in 0..3 {
        let heavy_addr = addr.clone();
        let heavy_body = heavy_path_body(&id);
        let heavy = std::thread::spawn(move || {
            let mut client = Client::connect(&heavy_addr).expect("connect heavy");
            client.request("POST", "/v1/path", &heavy_body).expect("heavy path request")
        });
        // Give the heavy request time to claim the slot before probing, so a
        // probe can never race it into the single slot.
        std::thread::sleep(std::time::Duration::from_millis(100));
        while !heavy.is_finished() {
            let mut probe = Client::connect(&addr).expect("connect probe");
            let (status, headers, body) =
                probe.request_full("GET", "/v1/health", "").expect("probe");
            if status == 503 {
                rejection = Some((headers, body));
                break;
            }
        }
        let (status, body) = heavy.join().expect("heavy thread");
        assert_eq!(status, 200, "round {round}: rejected-around request must complete: {body}");
        if rejection.is_some() {
            break;
        }
    }
    let (headers, body) = rejection.expect("no probe observed a full admission queue");
    assert!(
        headers.iter().any(|(name, value)| name == "retry-after" && value == "1"),
        "503 without Retry-After: {headers:?}"
    );
    assert!(body.contains("queue"), "busy body names the queue: {body}");

    // the rejection wedged nothing
    let (status, _) = setup.request("GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200);
    handle.stop();
}

/// A request whose whole time budget is spent waiting in the admission queue
/// is answered `503` (typed deadline expiry) without ever reaching the
/// solver — and the request holding the slot still completes.
#[test]
fn deadline_spent_in_queue_answers_503() {
    let prob = problem();
    let cfg = ServerConfig {
        port: 0,
        max_inflight: 1,
        queue_depth: 8,
        request_timeout_ms: 400,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).expect("bind").spawn().expect("spawn");
    let addr = handle.addr();
    let mut setup = Client::connect(&addr).unwrap();
    let id = register_dense(&mut setup, &prob.a, &prob.b);

    let mut expiry = None;
    for round in 0..3 {
        let heavy_addr = addr.clone();
        let heavy_body = heavy_path_body(&id);
        let heavy = std::thread::spawn(move || {
            let mut client = Client::connect(&heavy_addr).expect("connect heavy");
            client.request("POST", "/v1/path", &heavy_body).expect("heavy path request")
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        // The probe queues behind the heavy solve; its 400 ms budget expires
        // in the queue and it must be answered 503 rather than admitted.
        let mut probe = Client::connect(&addr).expect("connect probe");
        let (status, headers, body) = probe.request_full("GET", "/v1/health", "").expect("probe");
        if status == 503 {
            expiry = Some((headers, body));
        }
        let (status, body) = heavy.join().expect("heavy thread");
        assert_eq!(status, 200, "round {round}: slot holder must complete: {body}");
        if expiry.is_some() {
            break;
        }
    }
    let (headers, body) = expiry.expect("no probe expired in the queue");
    assert!(body.contains("deadline"), "expiry body names the deadline: {body}");
    assert!(
        headers.iter().any(|(name, value)| name == "retry-after" && value == "1"),
        "deadline 503 without Retry-After: {headers:?}"
    );

    // fresh connection: `setup` idled past the 400 ms budget and was closed
    let (status, _) = http_request(&addr, "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200);
    handle.stop();
}

/// Slow-loris shapes: a peer that sends a partial request and stalls is
/// answered `408` and closed (never a wedged connection thread), while a
/// keep-alive connection that goes quiet between requests closes silently —
/// and the server keeps answering either way.
#[test]
fn stalled_partial_request_answers_408_and_idle_closes_silently() {
    let cfg = ServerConfig { port: 0, request_timeout_ms: 250, ..ServerConfig::default() };
    let handle = Server::bind(cfg).expect("bind").spawn().expect("spawn");
    let addr = handle.addr();

    // partial headers, then silence → 408
    let mut stalled = Client::connect(&addr).unwrap();
    stalled.send_raw(b"POST /v1/fit HTTP/1.1\r\nhost: t\r\n").unwrap();
    let (status, body) = stalled.read_reply().expect("408 reply");
    assert_eq!(status, 408, "{body}");
    assert!(body.contains("stalled"), "{body}");

    // complete headers but a body that never arrives → 408
    let mut bodyless = Client::connect(&addr).unwrap();
    bodyless
        .send_raw(b"POST /v1/fit HTTP/1.1\r\nhost: t\r\ncontent-length: 10\r\n\r\n")
        .unwrap();
    let (status, body) = bodyless.read_reply().expect("408 reply");
    assert_eq!(status, 408, "{body}");

    // a quiet keep-alive connection closes with no response bytes at all
    let mut idle = Client::connect(&addr).unwrap();
    assert!(idle.read_reply().is_err(), "idle connection must close silently");

    let (status, _) = http_request(&addr, "GET", "/v1/health", "").unwrap();
    assert_eq!(status, 200, "server healthy after the stalls");
    handle.stop();
}

/// Programmatic graceful drain: once a drain begins, the in-flight request
/// runs to completion and is answered normally, while late connects are
/// refused (the listener closes).
#[test]
fn graceful_drain_finishes_inflight_and_refuses_new_connects() {
    let prob = problem();
    let handle = spawn_server(16, 0, 256 << 20);
    let addr = handle.addr();
    let mut setup = Client::connect(&addr).unwrap();
    let id = register_dense(&mut setup, &prob.a, &prob.b);

    let heavy_addr = addr.clone();
    let heavy_body = heavy_path_body(&id);
    let heavy = std::thread::spawn(move || {
        let mut client = Client::connect(&heavy_addr).expect("connect heavy");
        client.request("POST", "/v1/path", &heavy_body).expect("heavy path request")
    });

    // Wait until the heavy request is observably in flight (the stats probe
    // itself holds one slot, so in-flight ≥ 2 means the path solve is
    // running), then begin the drain around it.
    let observe_deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while !heavy.is_finished() && std::time::Instant::now() < observe_deadline {
        let mut probe = Client::connect(&addr).expect("connect probe");
        let (status, body) = probe.request("GET", "/v1/stats", "").expect("stats probe");
        assert_eq!(status, 200, "{body}");
        let inflight = Json::parse(&body)
            .expect("stats parse")
            .get("inflight")
            .and_then(Json::as_usize)
            .expect("inflight gauge");
        if inflight >= 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    handle.begin_drain();

    // the accept loop observes the flag within one poll and closes the
    // listener; from then on connects are refused
    let refuse_deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let mut refused = false;
    while std::time::Instant::now() < refuse_deadline {
        match std::net::TcpStream::connect(&addr) {
            Err(_) => {
                refused = true;
                break;
            }
            Ok(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    assert!(refused, "late connects must be refused once the drain begins");

    // the request that was in flight when the drain began completed normally
    let (status, body) = heavy.join().expect("heavy thread");
    assert_eq!(status, 200, "drain cut off an in-flight request: {body}");
    handle.stop();
}

/// SIGTERM against the real binary: the process stops accepting, finishes
/// its work, prints the drain message, and exits 0.
#[test]
#[cfg(unix)]
fn sigterm_drains_the_serve_process_cleanly() {
    use std::io::{BufRead, BufReader, Read};
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_ssnal-en"))
        .args(["serve", "--port", "0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve subprocess");
    let mut stdout = BufReader::new(child.stdout.take().expect("child stdout"));

    // banner: "ssnal-en serve listening on http://127.0.0.1:PORT (…)"
    let mut addr = None;
    let mut line = String::new();
    for _ in 0..50 {
        line.clear();
        if stdout.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.split("http://").nth(1) {
            addr = rest.split_whitespace().next().map(String::from);
            break;
        }
    }
    let addr = addr.expect("serve banner with a listen address");
    let (status, body) = http_request(&addr, "GET", "/v1/health", "").expect("health");
    assert_eq!(status, 200, "{body}");

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    assert_eq!(unsafe { kill(child.id() as i32, 15) }, 0, "deliver SIGTERM");

    let exit_deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let status = loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => break status,
            None if std::time::Instant::now() >= exit_deadline => {
                let _ = child.kill();
                panic!("serve did not exit within 30s of SIGTERM");
            }
            None => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    };
    assert!(status.success(), "SIGTERM drain must exit 0, got {status:?}");
    let mut rest = String::new();
    stdout.read_to_string(&mut rest).expect("drain output");
    assert!(rest.contains("drained cleanly"), "missing drain message: {rest:?}");
}

/// Concurrent single-`b` refits on one warm session coalesce into
/// `refit_many` batches without changing a byte: at solver thread budgets 1
/// and 4, every coalesced response equals the sequential direct-api refit,
/// and `/v1/stats` accounts for every one of them.
#[test]
fn coalesced_concurrent_refits_match_sequential_and_surface_in_stats() {
    use ssnal_en::api::StatsSnapshot;

    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let mut reference = EnetModel::new().alpha_c(0.8, 0.5).tol(TOL).fit(&design).unwrap();
    let m = prob.b.len();
    let response = |i: usize| -> Vec<f64> { (0..m).map(|k| prob.b[(k + i) % m]).collect() };
    let clients = 12;
    let mut expected = Vec::with_capacity(clients);
    for i in 0..clients {
        reference.refit(&response(i)).unwrap();
        expected.push(reference.export_json());
    }

    for budget in [1usize, 4] {
        let handle = spawn_server(16, budget, 256 << 20);
        let addr = handle.addr();
        let mut setup = Client::connect(&addr).unwrap();
        let id = register_dense(&mut setup, &prob.a, &prob.b);
        // All clients target the same design/model → the same session slot,
        // so concurrent single-b refits contend and coalesce.
        std::thread::scope(|scope| {
            let expected = &expected;
            let addr = &addr;
            let id = &id;
            let response = &response;
            let workers: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut client = Client::connect(addr).expect("connect");
                        let body = refit_body(id, 0.5, &response(c));
                        let (status, got) =
                            client.request("POST", "/v1/refit", &body).expect("refit");
                        assert_eq!(status, 200, "budget {budget}: {got}");
                        assert_eq!(
                            got, expected[c],
                            "budget {budget}: coalesced refit {c} diverges from sequential"
                        );
                    })
                })
                .collect();
            for w in workers {
                w.join().expect("client thread");
            }
        });

        // Every single-b refit flowed through the coalescer; the stats
        // surface must account for all of them, reject nothing, and expose
        // the warm session's workspace through the typed snapshot.
        let (status, body) = setup.request("GET", "/v1/stats", "").expect("stats");
        assert_eq!(status, 200, "{body}");
        let stats = Json::parse(&body).expect("stats parse");
        assert_eq!(stats.get("kind").and_then(Json::as_str), Some("ssnal_en.stats"));
        let counter = |obj: &str, key: &str| {
            stats.get(obj).and_then(|o| o.get(key)).and_then(Json::as_usize).expect(key)
        };
        assert_eq!(counter("queue", "rejected_full"), 0, "budget {budget}: {body}");
        assert_eq!(counter("coalesce", "requests"), clients, "budget {budget}: {body}");
        let batches = counter("coalesce", "batches");
        assert!(batches >= 1 && batches <= clients, "budget {budget}: {body}");
        let refit_count = stats
            .get("endpoints")
            .and_then(Json::as_arr)
            .and_then(|eps| {
                eps.iter()
                    .find(|e| e.get("endpoint").and_then(Json::as_str) == Some("refit"))
                    .and_then(|e| e.get("requests"))
                    .and_then(Json::as_usize)
            })
            .expect("refit endpoint metrics");
        assert_eq!(refit_count, clients, "budget {budget}: {body}");
        let workspace = stats
            .get("sessions")
            .and_then(Json::as_arr)
            .and_then(|sessions| {
                sessions.iter().find_map(|s| s.get("workspace").and_then(StatsSnapshot::from_json))
            })
            .expect("warm session workspace snapshot");
        assert!(workspace.events() > 0, "budget {budget}: {workspace:?}");
        // The rank-1 edit-tier counters are part of the schema (from_json
        // above already requires them); single-design refits never edit the
        // active design, so no downdate fallback may fire here.
        let ws_json = stats
            .get("sessions")
            .and_then(Json::as_arr)
            .and_then(|sessions| sessions.first())
            .and_then(|s| s.get("workspace"))
            .expect("workspace json");
        for key in ["rank1_updates", "rank1_downdates", "downdate_fallbacks"] {
            assert!(
                ws_json.get(key).and_then(Json::as_usize).is_some(),
                "budget {budget}: missing workspace counter {key}: {body}"
            );
        }
        assert_eq!(workspace.downdate_fallbacks, 0, "budget {budget}: {workspace:?}");
        handle.stop();
    }
}

/// `POST /v1/designs {"path": ...}` registers an on-disk out-of-core design
/// by reference — no matrix crosses the wire. The streamed fit is
/// byte-identical to the dense direct-api fit (f64 panels decode to exactly
/// the in-core columns and the same kernels run on both sides),
/// registration is idempotent on the file's content fingerprint, the design
/// body reports `"out_of_core"` storage, `/v1/stats` surfaces the session's
/// block-cache counters, and a dangling path answers 4xx, never a panic.
#[test]
fn ooc_path_registration_fits_bitwise_and_surfaces_cache_counters() {
    use ssnal_en::api::StatsSnapshot;
    use ssnal_en::linalg::ooc;

    let prob = problem();
    let design = Design::new(&prob.a, &prob.b).unwrap();
    let expected_fit =
        EnetModel::new().alpha_c(0.8, 0.5).tol(TOL).fit(&design).unwrap().export_json();

    let path = std::env::temp_dir().join(format!("ssnal_serve_ooc_{}.ooc", std::process::id()));
    ooc::write_design_f64(&path, (&prob.a).into(), 32).expect("write ooc design");

    let handle = spawn_server(16, 0, 256 << 20);
    let mut client = Client::connect(&handle.addr()).unwrap();

    // Register by path: a tiny JSON body instead of an m×n payload. A small
    // cache budget (two 32-column panels) keeps the streaming tier honest —
    // the solve below must evict and re-read to cover all 200 columns.
    let register = Json::obj(vec![
        ("path", Json::Str(path.display().to_string())),
        ("b", num_arr(&prob.b)),
        ("cache_bytes", Json::Num((2 * 32 * prob.a.rows() * 8) as f64)),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/designs", &register).unwrap();
    assert_eq!(status, 200, "path registration failed: {body}");
    let reg = Json::parse(&body).expect("registration response parses");
    assert_eq!(reg.get("storage").and_then(Json::as_str), Some("out_of_core"), "{body}");
    assert_eq!(reg.get("m").and_then(Json::as_usize), Some(prob.a.rows()), "{body}");
    assert_eq!(reg.get("n").and_then(Json::as_usize), Some(prob.a.cols()), "{body}");
    let id = reg
        .get("design_id")
        .and_then(|v| v.as_str().map(String::from))
        .expect("design_id present");

    // Re-registering the same file is a no-op: the design_id is derived from
    // the header's content hash, so the same bytes map to the same id.
    let (status, body) = client.request("POST", "/v1/designs", &register).unwrap();
    assert_eq!(status, 200, "{body}");
    let again = Json::parse(&body).expect("second registration parses");
    assert_eq!(again.get("design_id").and_then(Json::as_str), Some(id.as_str()), "{body}");

    // The fit streamed from disk matches the in-core fit byte for byte.
    let (status, body) = client.request("POST", "/v1/fit", &fit_body(&id, 0.5)).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body, expected_fit, "out-of-core server fit diverges from dense direct api");

    // The warm session's workspace snapshot must show the block cache at
    // work: the solve touched disk, so misses and streamed bytes are
    // nonzero (in-core sessions pin these counters at zero).
    let (status, body) = client.request("GET", "/v1/stats", "").unwrap();
    assert_eq!(status, 200, "{body}");
    let stats = Json::parse(&body).expect("stats parse");
    let workspace = stats
        .get("sessions")
        .and_then(Json::as_arr)
        .and_then(|sessions| {
            sessions.iter().find_map(|s| s.get("workspace").and_then(StatsSnapshot::from_json))
        })
        .expect("warm session workspace snapshot");
    assert!(workspace.ooc_cache_misses > 0, "no disk reads recorded: {workspace:?}");
    assert!(workspace.ooc_bytes_read > 0, "no bytes streamed: {workspace:?}");

    // A dangling path is a client error with the reason in the body, not a
    // panic and not a wedged server.
    let bad = Json::obj(vec![
        ("path", Json::Str("/nonexistent/definitely-missing.ooc".to_string())),
        ("b", num_arr(&prob.b)),
    ])
    .to_string();
    let (status, body) = client.request("POST", "/v1/designs", &bad).unwrap();
    assert!((400..500).contains(&status), "expected 4xx for a bad path, got {status}: {body}");

    // Mixing "path" with an inline payload is rejected outright.
    let mut mixed = dense_spec(&prob.a);
    mixed.push(("path", Json::Str(path.display().to_string())));
    mixed.push(("b", num_arr(&prob.b)));
    let (status, body) =
        client.request("POST", "/v1/designs", &Json::obj(mixed).to_string()).unwrap();
    assert_eq!(status, 400, "expected 400 for mixed path+inline spec: {body}");

    handle.stop();
    let _ = std::fs::remove_file(&path);
}
