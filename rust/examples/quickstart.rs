//! Quickstart: solve one ultra-high-dimensional Elastic Net with SsNAL-EN,
//! inspect the result, and cross-check against coordinate descent.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ssnal_en::coordinator::{Coordinator, CoordinatorConfig};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::solver::types::{Algorithm, EnetProblem};
use ssnal_en::solver::{kkt_residuals, solve_with};
use ssnal_en::util::timer::time_it;

fn main() -> ssnal_en::util::error::Result<()> {
    // 1. a synthetic instance in the paper's ultra-high-dimensional regime:
    //    n = 50 000 features, m = 500 observations, 20 true nonzeros.
    let spec = SyntheticSpec { m: 500, n: 50_000, n0: 20, x_star: 5.0, snr: 5.0, seed: 42 };
    println!("generating A ∈ R^{{{}×{}}} ...", spec.m, spec.n);
    let prob = generate_synthetic(&spec);

    // 2. the paper's λ parametrization: λ1 = α·c·λmax, λ2 = (1−α)·c·λmax.
    let alpha = 0.75;
    let lambda_max = EnetProblem::lambda_max(&prob.a, &prob.b, alpha);
    let (lam1, lam2) = EnetProblem::lambdas_from_alpha(alpha, 0.3, lambda_max);
    println!("λ_max = {lambda_max:.3}, λ1 = {lam1:.3}, λ2 = {lam2:.3}");

    // 3. solve with SsNAL-EN via the coordinator (native f64 backend).
    let coord = Coordinator::new(CoordinatorConfig::native(1e-6));
    let (fit, secs) = time_it(|| coord.solve(&prob.a, &prob.b, lam1, lam2));
    let fit = fit?;
    println!(
        "\nSsNAL-EN: {secs:.3}s — {} outer / {} inner iterations, residual {:.2e}",
        fit.iterations, fit.inner_iterations, fit.residual
    );
    println!("active set: {} features, objective {:.5}", fit.active_set.len(), fit.objective);

    // 4. verify the KKT system (Eq. 8/20) at the solution.
    let p = EnetProblem::new(&prob.a, &prob.b, lam1, lam2);
    let z: Vec<f64> = prob.a.t_mul_vec(&fit.y).iter().map(|v| -v).collect();
    let kkt = kkt_residuals(&p, &fit.x, &fit.y, &z);
    println!("KKT residuals: res1={:.2e} res2={:.2e} res3={:.2e}", kkt.res1, kkt.res2, kkt.res3);

    // 5. recovery of the true support.
    let hits = prob.support.iter().filter(|j| fit.x[**j] != 0.0).count();
    println!("true-support recovery: {hits}/{}", prob.support.len());

    // 6. cross-check against glmnet-style coordinate descent (same optimum).
    let (cd, cd_secs) = time_it(|| solve_with(&p, Algorithm::CdCovariance, 1e-8));
    let dist = ssnal_en::linalg::blas::dist2(&fit.x, &cd.x);
    println!(
        "\ncoordinate descent: {cd_secs:.3}s — ‖x_ssnal − x_cd‖ = {dist:.2e} \
         (speedup ×{:.1})",
        cd_secs / secs
    );
    Ok(())
}
