//! Quickstart: solve one ultra-high-dimensional Elastic Net through the
//! estimator facade, inspect the fit, re-score a second response on the warm
//! session, and cross-check against coordinate descent.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ssnal_en::api::{Design, EnetModel};
use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::solver::kkt_residuals;
use ssnal_en::solver::types::Algorithm;
use ssnal_en::util::timer::time_it;

fn main() -> ssnal_en::util::error::Result<()> {
    // 1. a synthetic instance in the paper's ultra-high-dimensional regime:
    //    n = 50 000 features, m = 500 observations, 20 true nonzeros.
    let spec = SyntheticSpec { m: 500, n: 50_000, n0: 20, x_star: 5.0, snr: 5.0, seed: 42 };
    println!("generating A ∈ R^{{{}×{}}} ...", spec.m, spec.n);
    let prob = generate_synthetic(&spec);

    // 2. validate once; every facade call reuses the checked design.
    let design = Design::new(&prob.a, &prob.b)?;
    println!("λ_max = {:.3}", design.lambda_max(0.75)?);

    // 3. fit SsNAL-EN via the facade (native f64 backend, the paper's
    //    λ1 = α·c·λmax parametrization).
    let model = EnetModel::new().alpha_c(0.75, 0.3).tol(1e-6);
    let (fit, secs) = time_it(|| model.fit(&design));
    let mut fit = fit?;
    let (lam1, lam2) = fit.lambdas();
    println!("λ1 = {lam1:.3}, λ2 = {lam2:.3}");
    let res = fit.result();
    println!(
        "\nSsNAL-EN: {secs:.3}s — {} outer / {} inner iterations, residual {:.2e}",
        res.iterations, res.inner_iterations, res.residual
    );
    println!("active set: {} features, objective {:.5}", fit.active_set().len(), res.objective);

    // 4. verify the KKT system (Eq. 8/20) at the solution.
    let p = design.problem(lam1, lam2);
    let z: Vec<f64> = prob.a.t_mul_vec(&res.y).iter().map(|v| -v).collect();
    let kkt = kkt_residuals(&p, fit.coefficients(), &res.y, &z);
    println!("KKT residuals: res1={:.2e} res2={:.2e} res3={:.2e}", kkt.res1, kkt.res2, kkt.res3);

    // 5. recovery of the true support, and in-sample predictions.
    let hits = prob.support.iter().filter(|j| fit.coefficients()[**j] != 0.0).count();
    println!("true-support recovery: {hits}/{}", prob.support.len());
    let preds = fit.predict(&prob.a)?;
    let mse = preds
        .iter()
        .zip(prob.b.iter())
        .map(|(p, b)| (p - b) * (p - b))
        .sum::<f64>()
        / preds.len() as f64;
    println!("in-sample MSE: {mse:.4}");

    // 6. cross-check against glmnet-style coordinate descent (same optimum),
    //    through the same facade — only the algorithm changes.
    let cd_model = EnetModel::new().lambda(lam1, lam2).algorithm(Algorithm::CdCovariance).tol(1e-8);
    let (cd, cd_secs) = time_it(|| cd_model.fit(&design));
    let cd = cd?;
    let dist = ssnal_en::linalg::blas::dist2(fit.coefficients(), cd.coefficients());
    println!(
        "\ncoordinate descent: {cd_secs:.3}s — ‖x_ssnal − x_cd‖ = {dist:.2e} \
         (speedup ×{:.1})",
        cd_secs / secs
    );

    // 7. warm session: re-score a scaled response on the same design — the
    //    fit's Newton workspace and Gram/Cholesky cache are reused
    //    (bitwise-identical to a cold fit, at workspace-cache cost).
    let b2: Vec<f64> = prob.b.iter().map(|v| 0.9 * v).collect();
    let sw = std::time::Instant::now();
    let refit_res = fit.refit(&b2)?;
    println!(
        "\nwarm refit on a new response: {:.3}s — active={}, converged={}",
        sw.elapsed().as_secs_f64(),
        refit_res.active_set.len(),
        refit_res.converged
    );
    Ok(())
}
