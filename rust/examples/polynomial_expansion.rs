//! The Table 2 workload: polynomial basis expansion of a small base table into
//! an ultra-high-dimensional, heavily collinear design — the regime the
//! Elastic Net (and SsNAL-EN) is built for.
//!
//! Demonstrates: LIBSVM-format round-trip, constant-column pruning, the
//! expansion itself (with the paper's exact feature counts), the collinearity
//! gauge ρ̂, and solver timing at two sparsity targets.
//!
//! ```bash
//! cargo run --release --example polynomial_expansion [max_features]
//! ```

use ssnal_en::bench::tables::c_lambda_for_active;
use ssnal_en::data::libsvm::{parse_libsvm, synthesize_base, to_libsvm, ReferenceSet};
use ssnal_en::data::polyexp::{drop_constant_columns, expand, expanded_count};
use ssnal_en::data::{center, rho_hat, standardize};
use ssnal_en::solver::types::{Algorithm, EnetProblem};
use ssnal_en::solver::solve_with;
use ssnal_en::util::timer::time_it;

fn main() -> ssnal_en::util::error::Result<()> {
    let max_n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);

    let set = ReferenceSet::Housing;
    let (name, m, d, order) = set.spec();
    println!(
        "dataset {name}: m={m}, {d} base features, order-{order} expansion \
         → full n = {} (paper: {})",
        expanded_count(d, order),
        set.paper_n()
    );

    // base table (synthesized offline substitute; see DESIGN.md §4) with a
    // LIBSVM-format round-trip to exercise the parser on realistic data
    let base = synthesize_base(set, 11);
    let text = to_libsvm(&base);
    let parsed = parse_libsvm(&text, 0).map_err(ssnal_en::util::error::Error::msg)?;
    assert_eq!(parsed.b.len(), base.b.len());
    println!("LIBSVM round-trip: {} rows, {} bytes", parsed.b.len(), text.len());

    let (clean, kept) = drop_constant_columns(&parsed.a, 1e-9);
    println!("constant-column pruning: kept {}/{} features", kept.len(), d);

    let ((expanded, _), secs) = time_it(|| expand(&clean, order, max_n));
    println!("expanded to n = {} in {secs:.2}s (truncated at {max_n})", expanded.cols());

    let std = standardize(&expanded);
    let (b, _) = center(&parsed.b);
    let rho = rho_hat(&std.a, 30, 0);
    println!("collinearity ρ̂ = λmax(AAᵀ)/n = {rho:.1}  (i.i.d. Gaussian designs give ≈1)");

    // Table 2 protocol: time the solvers at r = 20 and r = 5 actives, α = 0.8
    for target_r in [20usize, 5] {
        let (c, lam1, lam2) = c_lambda_for_active(&std.a, &b, 0.8, target_r, 30);
        let p = EnetProblem::new(&std.a, &b, lam1, lam2);
        let (ssnal, t_ssnal) = time_it(|| solve_with(&p, Algorithm::SsnalEn, 1e-6));
        let (cd, t_cd) = time_it(|| solve_with(&p, Algorithm::CdCovariance, 1e-6));
        println!(
            "r≈{target_r} (c_λ={c:.3}): ssnal-en {t_ssnal:.3}s ({} iters, r={}) | \
             cd-cov {t_cd:.3}s (r={}) | speedup ×{:.1}",
            ssnal.iterations,
            ssnal.active_set.len(),
            cd.active_set.len(),
            t_cd / t_ssnal
        );
    }
    Ok(())
}
