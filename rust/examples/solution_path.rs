//! Warm-started λ-path + parameter tuning (paper §3.3 / Supplement D.4).
//!
//! Traces the full regularization path on a sim1-style instance, shows how the
//! active set grows as c_λ decreases, compares path cost against coordinate
//! descent, and picks a model with GCV and e-BIC.
//!
//! ```bash
//! cargo run --release --example solution_path
//! ```

use ssnal_en::data::{generate_synthetic, SyntheticSpec};
use ssnal_en::path::{c_lambda_grid, PathOptions};
use ssnal_en::solver::types::Algorithm;
use ssnal_en::tuning::{tune, TuningOptions};
use ssnal_en::util::table::Table;
use ssnal_en::util::timer::time_it;

fn main() -> ssnal_en::util::error::Result<()> {
    // sim1 shape (scaled for an example): m=500, n₀=100 true features
    let spec = SyntheticSpec { m: 500, n: 20_000, n0: 100, x_star: 5.0, snr: 5.0, seed: 7 };
    println!("generating sim1-style instance ({}×{}) ...", spec.m, spec.n);
    let prob = generate_synthetic(&spec);

    // D.4 protocol: 100 log-spaced c_λ in [0.1, 1], stop at 100 active features
    let mk_opts = |algorithm| PathOptions {
        alpha: 0.8,
        c_grid: c_lambda_grid(1.0, 0.1, 100),
        max_active: 100,
        tol: 1e-6,
        algorithm,
    };

    let (path, secs) =
        time_it(|| ssnal_en::path::solve_path(&prob.a, &prob.b, &mk_opts(Algorithm::SsnalEn)));
    println!(
        "\nSsNAL-EN path: {} points in {secs:.2}s (truncated = {})",
        path.runs, path.truncated
    );

    let mut t = Table::new(&["c_lambda", "active", "outer", "inner"])
        .with_title("path milestones (every 5th point)");
    for p in path.points.iter().step_by(5) {
        t.row(vec![
            format!("{:.3}", p.c_lambda),
            format!("{}", p.result.active_set.len()),
            format!("{}", p.result.iterations),
            format!("{}", p.result.inner_iterations),
        ]);
    }
    t.print();

    let (path_cd, secs_cd) = time_it(|| {
        ssnal_en::path::solve_path(&prob.a, &prob.b, &mk_opts(Algorithm::CdCovariance))
    });
    println!(
        "\nglmnet-style CD path: {} points in {secs_cd:.2}s → SsNAL-EN speedup ×{:.1}",
        path_cd.runs,
        secs_cd / secs
    );

    // tuning criteria on a coarser grid (GCV + e-BIC; CV optional and costly)
    let topts = TuningOptions {
        path: PathOptions { c_grid: c_lambda_grid(0.99, 0.1, 30), ..mk_opts(Algorithm::SsnalEn) },
        cv_folds: 0,
        cv_seed: 0,
    };
    let (tuned, secs_tune) = time_it(|| tune(&prob.a, &prob.b, &topts));
    let g = &tuned.points[tuned.best_gcv];
    let e = &tuned.points[tuned.best_ebic];
    println!(
        "\ntuning ({secs_tune:.2}s): gcv picks c={:.3} (r={}), e-bic picks c={:.3} (r={}) — truth n₀={}",
        g.c_lambda, g.active, e.c_lambda, e.active, spec.n0
    );
    Ok(())
}
