//! END-TO-END driver (DESIGN.md §3, Figure 2 + Table 3): the full INSIGHT-style
//! GWAS workload through every layer of the system.
//!
//! Pipeline: simulate two SNP cohorts with LD-block structure (the privacy-
//! protected INSIGHT data's statistical stand-in) → standardized genotype
//! designs → warm-started SsNAL-EN λ-paths at three α values → GCV / e-BIC
//! tuning criteria → selected-SNP tables with de-biased coefficients →
//! criteria-curve CSVs (the Figure 2 series). It also executes one solve on the
//! **PJRT backend** (AOT-compiled JAX + Pallas artifacts) when artifacts are
//! available, proving all three layers compose on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example gwas_insight
//! ```
//!
//! The run (sizes, timings, recovery numbers) is recorded in EXPERIMENTS.md.

use ssnal_en::api::{Backend, Design, EnetModel};
use ssnal_en::bench::tables::{insight_run, INSIGHT_CURVE_HEADER};
use ssnal_en::data::snp::{generate as generate_snp, SnpSpec};
use ssnal_en::solver::types::{EnetProblem, NewtonStrategy};
use ssnal_en::util::csv::write_csv;
use ssnal_en::util::table::Table;
use ssnal_en::util::timer::time_it;
use std::path::PathBuf;

fn main() -> ssnal_en::util::error::Result<()> {
    let n_snps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let out_dir = PathBuf::from("results");

    // the two cohorts of the paper's §4.2 (m=226 / m=210; 13 / 6 selected SNPs)
    let cohorts = [
        (
            "cwg",
            SnpSpec {
                m: 226,
                n_snps,
                n_causal: 13,
                dominant_effect: 1.2,
                seed: 2020,
                ..Default::default()
            },
        ),
        (
            "bmi",
            SnpSpec {
                m: 210,
                n_snps,
                n_causal: 6,
                dominant_effect: 1.4,
                seed: 2021,
                ..Default::default()
            },
        ),
    ];
    let alphas = [0.9, 0.8, 0.6];

    for (name, spec) in &cohorts {
        println!(
            "=== cohort {name}: m={}, {} SNPs, {} causal ===",
            spec.m, spec.n_snps, spec.n_causal
        );
        let (run, secs) = time_it(|| insight_run(spec, &alphas, 25, 0));
        println!(
            "tuning sweep over α ∈ {alphas:?}: {secs:.1}s, {} curve rows",
            run.curves.len()
        );

        let curve_path = out_dir.join(format!("fig2_{name}.csv"));
        write_csv(&curve_path, &INSIGHT_CURVE_HEADER, &run.curves)?;
        println!("Figure 2 series → {}", curve_path.display());

        let mut t = Table::new(&["snp", "coef", "is_causal"])
            .with_title(&format!("Table 3 ({name}): selected at the e-BIC optimum"));
        for (snp, coef) in &run.selected {
            t.row(vec![snp.clone(), format!("{coef:.3}"), format!("{}", run.causal.contains(snp))]);
        }
        t.print();
        let hits = run.selected.iter().filter(|(s, _)| run.causal.contains(s)).count();
        println!("causal recovery: {hits}/{} selected are truly causal\n", run.selected.len());
    }

    // --- three-layer composition: one solve on the PJRT backend -------------
    let artifacts = ssnal_en::runtime::default_artifacts_dir();
    if artifacts.join("manifest.json").exists() {
        // artifacts ship a (200, 4096) shape — build a matching mini-cohort
        let spec = SnpSpec {
            m: 200,
            n_snps: 4096,
            n_causal: 5,
            dominant_effect: 2.0,
            seed: 7,
            ..Default::default()
        };
        let cohort = generate_snp(&spec);
        let lmax = EnetProblem::lambda_max(&cohort.a, &cohort.b, 0.9);
        let (l1, l2) = EnetProblem::lambdas_from_alpha(0.9, 0.5, lmax);
        let design = Design::new(&cohort.a, &cohort.b)?;

        let native = EnetModel::new().lambda(l1, l2).tol(1e-8);
        let (fit_native, t_native) = time_it(|| native.fit(&design));
        let fit_native = fit_native?.into_result();

        // f32 artifacts: matrix-free CG strategy, looser tolerance.
        let pjrt = EnetModel::new()
            .lambda(l1, l2)
            .backend(Backend::Pjrt)
            .artifacts_dir(artifacts)
            .tol(1e-4)
            .newton(NewtonStrategy::ConjugateGradient);
        let (fit_pjrt, t_pjrt) = time_it(|| pjrt.fit(&design).map(|f| f.into_result()));
        match fit_pjrt {
            Ok(fit_pjrt) => {
                let dist = ssnal_en::linalg::blas::dist2(&fit_native.x, &fit_pjrt.x);
                println!(
                    "=== PJRT three-layer check (200×4096 SNP cohort) ===\n\
                     native  : {t_native:.3}s, active={}, obj={:.5}\n\
                     pjrt    : {t_pjrt:.3}s, active={}, obj={:.5} (AOT JAX+Pallas, f32)\n\
                     ‖x_native − x_pjrt‖ = {dist:.2e}",
                    fit_native.active_set.len(),
                    fit_native.objective,
                    fit_pjrt.active_set.len(),
                    fit_pjrt.objective
                );
            }
            Err(e) => println!("(PJRT backend unavailable in this build: {e})"),
        }
    } else {
        println!("(artifacts not built — run `make artifacts` to include the PJRT check)");
    }
    Ok(())
}
