"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + manifest.json.

This is the only place Python runs in the whole system, and it runs once
(`make artifacts`). The interchange format is **HLO text**, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--shapes 200x4000,500x10240]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Default shapes: a small one for quick tests/examples and a bench-sized one.
# n must be divisible by the kernel tile (512) for the Pallas BlockSpec.
DEFAULT_SHAPES = [(200, 4096), (500, 10240)]

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def graph_specs(m: int, n: int):
    """(name, function, example_args) for every graph, at shape (m, n)."""
    at = jax.ShapeDtypeStruct((n, m), F32)
    vec_m = jax.ShapeDtypeStruct((m,), F32)
    vec_n = jax.ShapeDtypeStruct((n,), F32)
    scalar = jax.ShapeDtypeStruct((), F32)
    return [
        ("dual_prox_grad", model.dual_prox_grad, (at, vec_m, vec_n, vec_m, scalar, scalar, scalar)),
        ("hess_vec", model.hess_vec, (at, vec_n, scalar, vec_m)),
        ("al_update", model.al_update, (vec_n, vec_n)),
    ]


def lower_all(shapes, out_dir: str, verbose: bool = True) -> dict:
    """Lower every graph at every shape; write HLO files and the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"dtype": "f32", "artifacts": []}
    for m, n in shapes:
        for name, fn, args in graph_specs(m, n):
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{name}_{m}x{n}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append({"name": name, "m": m, "n": n, "file": fname})
            if verbose:
                print(f"  lowered {name} ({m}x{n}) -> {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote manifest with {len(manifest['artifacts'])} artifacts to {out_dir}")
    return manifest


def parse_shapes(text: str):
    """Parse `200x4096,500x10240` into [(200, 4096), (500, 10240)]."""
    shapes = []
    for part in text.split(","):
        ms, ns = part.lower().split("x")
        shapes.append((int(ms), int(ns)))
    return shapes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--shapes", default=None, help="comma list like 200x4096,500x10240")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    shapes = parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPES
    lower_all(shapes, args.out_dir, verbose=not args.quiet)


if __name__ == "__main__":
    main()
