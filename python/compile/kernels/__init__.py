"""L1 Pallas kernels and their pure-jnp reference oracle."""

from compile.kernels import ref  # noqa: F401
from compile.kernels.prox_enet import dual_prox_sweep  # noqa: F401
