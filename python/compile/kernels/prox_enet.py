"""L1 Pallas kernel: the fused dual-feasibility + prox sweep of SsNAL-EN.

The solve-path hot spot over the huge n-dimension is

    t    = x - sigma * (A^T y)        # the O(mn) dual sweep
    u    = prox_{sigma p}(t)          # Eq. (6), scaled soft-threshold
    mask = 1{|t| > sigma*lam1}        # the active set J (Eq. 17)

This kernel fuses all three so `t` never round-trips to HBM. The n-axis is
tiled with BlockSpec: each grid step loads one (bn, m) block of `at` (the
transposed design) into VMEM, computes the block mat-vec on the MXU, and
applies the elementwise prox in-register.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU BLAS
`A^T y` becomes a VMEM-tiled MXU contraction; the prox/mask is the epilogue of
the same tile. `interpret=True` everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU efficiency is estimated in EXPERIMENTS.md §Perf.

VMEM budget per grid step (f32): bn*m (at tile) + m (y) + 4*bn (x, t, u, mask)
bytes*4. With bn=512, m=500: ~1.05 MB — comfortably inside the ~16 MB VMEM of a
TPU core, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default n-axis tile. Multiple of 128 (lane width); see VMEM budget above.
DEFAULT_BLOCK_N = 512


def _kernel(at_ref, y_ref, x_ref, scal_ref, t_ref, u_ref, mask_ref):
    """One (bn,)-tile of the fused sweep.

    scal_ref packs (sigma, lam1, lam2) as a length-3 vector so the penalty
    parameters stay runtime inputs (the artifacts would otherwise bake them).
    """
    sigma = scal_ref[0]
    lam1 = scal_ref[1]
    lam2 = scal_ref[2]
    # (bn, m) @ (m,) on the MXU
    aty = jnp.dot(at_ref[...], y_ref[...], preferred_element_type=jnp.float32)
    t = x_ref[...] - sigma * aty
    thr = sigma * lam1
    scale = 1.0 / (1.0 + sigma * lam2)
    u = jnp.sign(t) * jnp.maximum(jnp.abs(t) - thr, 0.0) * scale
    t_ref[...] = t
    u_ref[...] = u
    mask_ref[...] = (jnp.abs(t) > thr).astype(t.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def dual_prox_sweep(at, x, y, sigma, lam1, lam2, *, block_n: int = DEFAULT_BLOCK_N):
    """Fused `t = x - sigma*A^T y`, `u = prox_{sigma p}(t)`, `mask` via Pallas.

    Args:
      at: transposed design, shape (n, m). n must be divisible by `block_n`
          (aot.py checks; pad the design if needed).
      x:  multiplier iterate, shape (n,).
      y:  dual iterate, shape (m,).
      sigma, lam1, lam2: scalars (traced — stay runtime inputs in the HLO).
      block_n: n-axis tile size.

    Returns:
      (t, u, mask), each shape (n,).
    """
    n, m = at.shape
    if n % block_n != 0:
        raise ValueError(f"n={n} must be divisible by block_n={block_n}")
    grid = (n // block_n,)
    scal = jnp.stack(
        [
            jnp.asarray(sigma, jnp.float32),
            jnp.asarray(lam1, jnp.float32),
            jnp.asarray(lam2, jnp.float32),
        ]
    )
    out_shape = [
        jax.ShapeDtypeStruct((n,), jnp.float32),  # t
        jax.ShapeDtypeStruct((n,), jnp.float32),  # u
        jax.ShapeDtypeStruct((n,), jnp.float32),  # mask
    ]
    vec_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),  # at tile
            pl.BlockSpec((m,), lambda i: (0,)),  # y (replicated)
            vec_spec,  # x tile
            pl.BlockSpec((3,), lambda i: (0,)),  # scalars (replicated)
        ],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(at.astype(jnp.float32), y.astype(jnp.float32), x.astype(jnp.float32), scal)


def vmem_bytes(block_n: int, m: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (used by the §Perf analysis)."""
    tile = block_n * m          # at tile
    vectors = m + 4 * block_n   # y + x/t/u/mask tiles
    scalars = 3
    return dtype_bytes * (tile + vectors + scalars)


def mxu_utilization_estimate(block_n: int, m: int) -> float:
    """Crude MXU utilization bound for the (bn, m) x (m,) contraction.

    A mat-vec feeds only one column of the 128x128 MXU per pass, so the
    theoretical ceiling is m/128 rounded-up occupancy over the systolic array;
    what rescues throughput is that the sweep is bandwidth-bound: the figure of
    merit is HBM bytes per FLOP, reported in EXPERIMENTS.md §Perf.
    """
    lanes = 128.0
    return min(1.0, (m % 128 or 128) / lanes)
