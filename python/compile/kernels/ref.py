"""Pure-jnp oracle for the L1 Pallas kernel and the L2 graphs.

These are the reference semantics — the closed forms of the paper's Eq. (3),
(5), (6) and Proposition 2 — written in plain jax.numpy with no Pallas. The
pytest suite asserts the Pallas kernel and the lowered HLO agree with these to
float tolerance, and the Rust test-suite implements the same formulas in f64.
"""

from __future__ import annotations

import jax.numpy as jnp


def soft_threshold(t, thr):
    """Scalar/vector soft-thresholding operator (Eq. 5, left)."""
    return jnp.sign(t) * jnp.maximum(jnp.abs(t) - thr, 0.0)


def prox_enet(t, sigma, lam1, lam2):
    """`prox_{sigma p}(t)` for the Elastic Net penalty (Eq. 6, left)."""
    return soft_threshold(t, sigma * lam1) / (1.0 + sigma * lam2)


def prox_enet_conj(t, sigma, lam1, lam2):
    """`prox_{p*/sigma}(t/sigma)` (Eq. 6, right); `t` is the pre-division argument."""
    thr = sigma * lam1
    upper = (t * lam2 + lam1) / (1.0 + sigma * lam2)
    lower = (t * lam2 - lam1) / (1.0 + sigma * lam2)
    mid = t / sigma
    return jnp.where(t >= thr, upper, jnp.where(t <= -thr, lower, mid))


def active_mask(t, sigma, lam1):
    """Indicator of the active set J = {j : |t_j| > sigma*lam1} (Eq. 17)."""
    return (jnp.abs(t) > sigma * lam1).astype(t.dtype)


def enet_penalty(x, lam1, lam2):
    """`p(x) = lam1*||x||_1 + (lam2/2)*||x||_2^2`."""
    return lam1 * jnp.sum(jnp.abs(x)) + 0.5 * lam2 * jnp.sum(x * x)


def enet_conjugate(z, lam1, lam2):
    """`p*(z)` (Proposition 1, Eq. 3). Requires lam2 > 0."""
    d = soft_threshold(z, lam1)
    return jnp.sum(d * d) / (2.0 * lam2)


def h_star(y, b):
    """`h*(y) = 0.5*||y||^2 + b^T y` for `h(u) = 0.5*||u - b||^2`."""
    return 0.5 * jnp.sum(y * y) + jnp.dot(b, y)


def dual_prox_sweep_ref(at, x, y, sigma, lam1, lam2):
    """Reference for the fused L1 kernel: `t = x - sigma*A^T y`, prox, mask.

    `at` is the transposed design (n, m) — see DESIGN.md (the Rust side passes
    its column-major storage directly as a row-major (n, m) buffer).
    """
    t = x - sigma * (at @ y)
    u = prox_enet(t, sigma, lam1, lam2)
    mask = active_mask(t, sigma, lam1)
    return t, u, mask


def dual_prox_grad_ref(at, b, x, y, sigma, lam1, lam2):
    """Reference for the L2 `dual_prox_grad` graph (Proposition 2 + Eq. 15).

    Returns (grad_psi(y), u, mask, psi(y)).
    """
    t, u, mask = dual_prox_sweep_ref(at, x, y, sigma, lam1, lam2)
    grad = y + b - u @ at  # A.u = at^T u = u @ at
    psi = (
        h_star(y, b)
        + (1.0 + sigma * lam2) / (2.0 * sigma) * jnp.sum(u * u)
        - jnp.sum(x * x) / (2.0 * sigma)
    )
    return grad, u, mask, psi


def hess_vec_ref(at, mask, kappa, d):
    """Reference for the L2 `hess_vec` graph: `(I + kappa*A_J A_J^T) d` (Eq. 18).

    The active set enters through the 0/1 `mask` (Q's support, Eq. 17); the
    `1/(1+sigma*lam2)` factor of Q is folded into `kappa = sigma/(1+sigma*lam2)`
    by the caller.
    """
    atd = at @ d
    return d + kappa * ((mask * atd) @ at)
