"""L2: the SsNAL-EN building blocks as JAX graphs (build-time only).

Each function here is jitted and AOT-lowered by `aot.py` to HLO text; the Rust
runtime (`rust/src/runtime/`) loads and executes the artifacts on the PJRT CPU
client. The control flow (AL outer loop, SsN inner loop, CG, line search)
lives in Rust — these graphs are the numerical building blocks, so they stay
loop-free and shape-static.

Conventions (shared with `rust/src/runtime/engine.rs`):
  * the design is passed transposed (`at`, shape (n, m)) — the Rust side's
    column-major storage is exactly this row-major buffer,
  * all buffers are f32,
  * functions return tuples (lowered with return_tuple=True).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.prox_enet import DEFAULT_BLOCK_N, dual_prox_sweep


def dual_prox_grad(at, b, x, y, sigma, lam1, lam2):
    """One fused evaluation of Proposition 2 / Eq. (15):

        t     = x - sigma * A^T y          (L1 Pallas kernel)
        u     = prox_{sigma p}(t)          (L1 Pallas kernel)
        mask  = 1{|t| > sigma lam1}        (L1 Pallas kernel)
        grad  = y + b - A u                (Eq. 15)
        psi   = h*(y) + (1+sigma lam2)/(2 sigma) ||u||^2 - ||x||^2/(2 sigma)

    Returns (grad, u, mask, psi).
    """
    n = at.shape[0]
    block_n = DEFAULT_BLOCK_N if n % DEFAULT_BLOCK_N == 0 else _largest_tile(n)
    _, u, mask = dual_prox_sweep(at, x, y, sigma, lam1, lam2, block_n=block_n)
    grad = y + b - u @ at
    psi = (
        ref.h_star(y, b)
        + (1.0 + sigma * lam2) / (2.0 * sigma) * jnp.sum(u * u)
        - jnp.sum(x * x) / (2.0 * sigma)
    )
    return grad, u, mask, psi


def hess_vec(at, mask, kappa, d):
    """Generalized-Hessian mat-vec `(I + kappa A_J A_J^T) d` (Eq. 18).

    Used by the matrix-free CG strategy on the PJRT backend. Returns a 1-tuple.
    """
    atd = at @ d
    return (d + kappa * ((mask * atd) @ at),)


def al_update(x, u):
    """AL multiplier update (Moreau identity form of Eq. 10): x <- u, plus the
    kkt3 residual numerator ||x - u||_2 the outer loop checks (Eq. 20; the
    denominator's sigma and norm terms are cheap host-side scalars).

    Returns (x_next, dist).
    """
    d = x - u
    return (u, jnp.sqrt(jnp.sum(d * d)))


def _largest_tile(n: int) -> int:
    """Largest power-of-two tile (<= DEFAULT_BLOCK_N) dividing n."""
    t = DEFAULT_BLOCK_N
    while t > 1 and n % t != 0:
        t //= 2
    return t
