"""L2 correctness: the jitted graphs vs the oracle, gradients vs finite
differences, and the internal consistency results of the paper (Prop. 2)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def case(n=512, m=24, seed=0):
    rng = np.random.default_rng(seed)
    at = rng.standard_normal((n, m)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    return at, b, x, y


class TestDualProxGrad:
    def test_matches_reference(self):
        at, b, x, y = case()
        g, u, mask, psi = model.dual_prox_grad(at, b, x, y, 0.7, 0.9, 1.1)
        g2, u2, m2, psi2 = ref.dual_prox_grad_ref(at, b, x, y, 0.7, 0.9, 1.1)
        np.testing.assert_allclose(g, g2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(u, u2, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(m2))
        np.testing.assert_allclose(float(psi), float(psi2), rtol=1e-4)

    def test_grad_is_dpsi_dy_finite_difference(self):
        # psi is C^1 (paper Section 3.1) — check grad against central differences
        # in f64 through the reference implementation.
        rng = np.random.default_rng(1)
        n, m = 64, 6
        at = rng.standard_normal((n, m))
        b = rng.standard_normal(m)
        x = rng.standard_normal(n)
        y = rng.standard_normal(m)
        sigma, lam1, lam2 = 0.6, 0.8, 0.9

        def psi_of(yv):
            _, _, _, psi = ref.dual_prox_grad_ref(at, b, x, yv, sigma, lam1, lam2)
            return float(psi)

        grad, _, _, _ = ref.dual_prox_grad_ref(at, b, x, y, sigma, lam1, lam2)
        eps = 1e-6
        for i in range(m):
            e = np.zeros(m)
            e[i] = eps
            fd = (psi_of(y + e) - psi_of(y - e)) / (2 * eps)
            assert abs(fd - float(grad[i])) < 1e-4, f"coord {i}: {fd} vs {grad[i]}"

    def test_psi_matches_lagrangian_definition(self):
        # Prop 2 part 1: psi(y) = L_sigma(y | z_bar, x) with
        # z_bar = prox_{p*/sigma}(x/sigma - A^T y). Check against the raw
        # Lagrangian formula (7).
        rng = np.random.default_rng(2)
        n, m = 40, 5
        at = rng.standard_normal((n, m))
        b = rng.standard_normal(m)
        x = rng.standard_normal(n)
        y = rng.standard_normal(m)
        sigma, lam1, lam2 = 1.3, 0.7, 0.5

        t = x - sigma * (at @ y)
        zbar = ref.prox_enet_conj(jnp.asarray(t), sigma, lam1, lam2)
        aty = at @ y
        constraint = aty + np.asarray(zbar)
        lag = (
            float(ref.h_star(jnp.asarray(y), jnp.asarray(b)))
            + float(ref.enet_conjugate(zbar, lam1, lam2))
            - float(np.dot(x, constraint))
            + 0.5 * sigma * float(np.dot(constraint, constraint))
        )
        _, _, _, psi = ref.dual_prox_grad_ref(at, b, x, y, sigma, lam1, lam2)
        np.testing.assert_allclose(lag, float(psi), rtol=1e-9)


class TestHessVec:
    def test_matches_reference(self):
        at, _, _, y = case(seed=3)
        n, m = at.shape
        rng = np.random.default_rng(4)
        mask = (rng.random(n) < 0.1).astype(np.float32)
        d = rng.standard_normal(m).astype(np.float32)
        (out,) = model.hess_vec(at, mask, 0.8, d)
        expected = ref.hess_vec_ref(at, mask, 0.8, d)
        np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-4)

    def test_empty_mask_is_identity(self):
        at, _, _, _ = case(seed=5)
        m = at.shape[1]
        d = np.arange(m, dtype=np.float32)
        (out,) = model.hess_vec(at, np.zeros(at.shape[0], np.float32), 0.8, d)
        np.testing.assert_allclose(out, d, atol=1e-6)

    def test_operator_is_spd(self):
        # x^T V x >= ||x||^2 for any direction (V = I + kappa A_J A_J^T)
        at, _, _, _ = case(n=128, m=10, seed=6)
        rng = np.random.default_rng(7)
        mask = (rng.random(128) < 0.3).astype(np.float32)
        for _ in range(5):
            d = rng.standard_normal(10).astype(np.float32)
            (vd,) = model.hess_vec(at, mask, 1.7, d)
            quad = float(np.dot(d, np.asarray(vd)))
            assert quad >= float(np.dot(d, d)) * (1 - 1e-4)


class TestAlUpdate:
    def test_returns_u_and_distance(self):
        x = np.ones(8, np.float32)
        u = np.arange(8, dtype=np.float32)
        out, dist = model.al_update(x, u)
        np.testing.assert_array_equal(np.asarray(out), u)
        expected = float(np.linalg.norm(x - u))
        assert abs(float(dist) - expected) < 1e-5
