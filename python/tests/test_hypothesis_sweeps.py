"""Property-based sweeps (hypothesis) over the L1 kernel's shapes, dtypes and
parameter space — the paper's prox identities must hold everywhere."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.prox_enet import dual_prox_sweep

jax.config.update("jax_platform_name", "cpu")

# keep each case small: interpret-mode Pallas is slow
SHAPES = st.sampled_from([(128, 1), (128, 7), (256, 16), (512, 33), (256, 64)])
POS = st.floats(min_value=1e-3, max_value=10.0, allow_nan=False)
NONNEG = st.floats(min_value=0.0, max_value=10.0, allow_nan=False)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, sigma=POS, lam1=NONNEG, lam2=NONNEG, seed=SEEDS)
def test_kernel_matches_oracle_everywhere(shape, sigma, lam1, lam2, seed):
    n, m = shape
    rng = np.random.default_rng(seed)
    at = rng.standard_normal((n, m)).astype(np.float32)
    x = (10.0 * rng.standard_normal(n)).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    t, u, mask = dual_prox_sweep(at, x, y, sigma, lam1, lam2, block_n=128)
    t2, u2, m2 = ref.dual_prox_sweep_ref(at, x, y, sigma, lam1, lam2)
    scale = float(np.max(np.abs(np.asarray(t2)))) + 1.0
    np.testing.assert_allclose(t, t2, rtol=1e-4, atol=1e-5 * scale)
    np.testing.assert_allclose(u, u2, rtol=1e-4, atol=1e-5 * scale)
    # masks may legitimately differ where |t| sits within f32 noise of the
    # threshold; require agreement away from the boundary.
    tt = np.asarray(t2)
    thr = sigma * lam1
    safe = np.abs(np.abs(tt) - thr) > 1e-3 * (1.0 + thr)
    np.testing.assert_array_equal(np.asarray(mask)[safe], np.asarray(m2)[safe])


@settings(max_examples=40, deadline=None)
@given(sigma=POS, lam1=NONNEG, lam2=NONNEG, seed=SEEDS)
def test_moreau_identity_random_parameters(sigma, lam1, lam2, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * 5.0)
    lhs = ref.prox_enet(x, sigma, lam1, lam2) + sigma * ref.prox_enet_conj(
        x, sigma, lam1, lam2
    )
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(x), rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(sigma=POS, lam1=POS, lam2=POS, seed=SEEDS)
def test_prox_is_minimizer(sigma, lam1, lam2, seed):
    # prox_{sigma p}(t) minimizes p(v) + (1/2 sigma)||v - t||^2 (Eq. 4):
    # compare against perturbations.
    rng = np.random.default_rng(seed)
    t = rng.standard_normal(16) * 3.0
    star = np.asarray(ref.prox_enet(jnp.asarray(t), sigma, lam1, lam2))

    def obj(v):
        return (
            lam1 * np.abs(v).sum()
            + 0.5 * lam2 * (v * v).sum()
            + ((v - t) ** 2).sum() / (2 * sigma)
        )

    f_star = obj(star)
    for _ in range(8):
        v = star + rng.standard_normal(16) * 0.1
        assert f_star <= obj(v) + 1e-9


@settings(max_examples=30, deadline=None)
@given(lam1=POS, lam2=POS, seed=SEEDS)
def test_conjugate_dominates_linear_minus_penalty(lam1, lam2, seed):
    # p*(z) >= x.z - p(x) for random x, z (Fenchel-Young).
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(8) * 2.0
    z = rng.standard_normal(8) * 2.0
    pstar = float(ref.enet_conjugate(jnp.asarray(z), lam1, lam2))
    lin = float(np.dot(x, z)) - float(ref.enet_penalty(jnp.asarray(x), lam1, lam2))
    assert pstar >= lin - 1e-8


@settings(max_examples=20, deadline=None)
@given(seed=SEEDS, kappa=POS)
def test_hess_vec_symmetry(seed, kappa):
    # V is symmetric: d1.V(d2) == d2.V(d1)
    rng = np.random.default_rng(seed)
    n, m = 128, 9
    at = rng.standard_normal((n, m))
    mask = (rng.random(n) < 0.25).astype(float)
    d1 = rng.standard_normal(m)
    d2 = rng.standard_normal(m)
    v1 = np.asarray(ref.hess_vec_ref(at, mask, kappa, d1))
    v2 = np.asarray(ref.hess_vec_ref(at, mask, kappa, d2))
    np.testing.assert_allclose(np.dot(d1, v2), np.dot(d2, v1), rtol=1e-9)
