"""Test configuration: enable f64 (the closed-form identity tests need it;
the Pallas kernel casts its own inputs to f32 regardless)."""

import jax

jax.config.update("jax_enable_x64", True)
jax.config.update("jax_platform_name", "cpu")
