"""AOT pipeline: lowering produces parseable HLO text + a consistent manifest,
and the lowered computation is numerically identical to eager execution."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


class TestShapeParsing:
    def test_parse_shapes(self):
        assert aot.parse_shapes("200x4096,500x10240") == [(200, 4096), (500, 10240)]
        assert aot.parse_shapes("8X512") == [(8, 512)]

    def test_default_shapes_tile_divisible(self):
        from compile.kernels.prox_enet import DEFAULT_BLOCK_N

        for _, n in aot.DEFAULT_SHAPES:
            assert n % DEFAULT_BLOCK_N == 0


class TestLowering:
    @pytest.fixture(scope="class")
    def artifacts(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = aot.lower_all([(8, 512)], str(out), verbose=False)
        return out, manifest

    def test_manifest_structure(self, artifacts):
        out, manifest = artifacts
        assert manifest["dtype"] == "f32"
        names = {a["name"] for a in manifest["artifacts"]}
        assert names == {"dual_prox_grad", "hess_vec", "al_update"}
        # manifest file round-trips as JSON
        with open(os.path.join(out, "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded == manifest

    def test_hlo_files_exist_and_are_text(self, artifacts):
        out, manifest = artifacts
        for art in manifest["artifacts"]:
            path = os.path.join(out, art["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "HloModule" in text, "HLO text format expected"
            # parameters stay runtime inputs — lambda must NOT be baked in
            assert "parameter(0)" in text

    def test_hlo_is_pure_ops_no_custom_calls(self, artifacts):
        # interpret=True Pallas must lower to plain HLO the CPU PJRT can run —
        # a Mosaic custom-call would break the Rust loader.
        out, manifest = artifacts
        for art in manifest["artifacts"]:
            text = open(os.path.join(out, art["file"])).read()
            assert "custom-call" not in text.lower(), art["file"]


class TestLoweredNumerics:
    """Compile the lowered StableHLO back through jax and compare to eager."""

    def test_dual_prox_grad_roundtrip(self):
        m, n = 8, 512
        rng = np.random.default_rng(0)
        at = rng.standard_normal((n, m)).astype(np.float32)
        b = rng.standard_normal(m).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        y = rng.standard_normal(m).astype(np.float32)
        args = (at, b, x, y, np.float32(0.7), np.float32(0.9), np.float32(0.4))
        eager = model.dual_prox_grad(*args)
        compiled = jax.jit(model.dual_prox_grad).lower(*args).compile()
        lowered_out = compiled(*args)
        for e, l in zip(eager, lowered_out):
            np.testing.assert_allclose(np.asarray(e), np.asarray(l), rtol=1e-5, atol=1e-5)

    def test_hess_vec_roundtrip(self):
        m, n = 8, 512
        rng = np.random.default_rng(1)
        at = rng.standard_normal((n, m)).astype(np.float32)
        mask = (rng.random(n) < 0.2).astype(np.float32)
        d = rng.standard_normal(m).astype(np.float32)
        args = (at, mask, np.float32(1.3), d)
        (eager,) = model.hess_vec(*args)
        compiled = jax.jit(model.hess_vec).lower(*args).compile()
        (lowered_out,) = compiled(*args)
        np.testing.assert_allclose(
            np.asarray(eager), np.asarray(lowered_out), rtol=1e-5, atol=1e-5
        )
