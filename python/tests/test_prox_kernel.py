"""L1 correctness: the Pallas kernel vs the pure-jnp oracle and closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.prox_enet import (
    DEFAULT_BLOCK_N,
    dual_prox_sweep,
    mxu_utilization_estimate,
    vmem_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def random_case(n, m, seed=0):
    rng = np.random.default_rng(seed)
    at = rng.standard_normal((n, m)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    return at, x, y


class TestKernelVsReference:
    @pytest.mark.parametrize("n,m", [(512, 16), (1024, 37), (2048, 200), (512, 1)])
    def test_matches_reference(self, n, m):
        at, x, y = random_case(n, m, seed=n + m)
        t, u, mask = dual_prox_sweep(at, x, y, 0.5, 0.8, 1.2)
        t2, u2, m2 = ref.dual_prox_sweep_ref(at, x, y, 0.5, 0.8, 1.2)
        np.testing.assert_allclose(t, t2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(u, u2, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(m2))

    @pytest.mark.parametrize("block_n", [128, 256, 512])
    def test_block_size_invariance(self, block_n):
        at, x, y = random_case(1024, 50, seed=3)
        t0, u0, m0 = dual_prox_sweep(at, x, y, 1.0, 1.0, 1.0, block_n=block_n)
        t1, u1, m1 = dual_prox_sweep(at, x, y, 1.0, 1.0, 1.0, block_n=1024)
        np.testing.assert_allclose(t0, t1, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(u0, u1, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))

    def test_rejects_indivisible_n(self):
        at, x, y = random_case(1000, 10, seed=4)
        with pytest.raises(ValueError, match="divisible"):
            dual_prox_sweep(at, x, y, 1.0, 1.0, 1.0, block_n=DEFAULT_BLOCK_N)

    def test_lambda_zero_reduces_to_dual_sweep(self):
        # lam1 = lam2 = 0: u = t = x - sigma*A^T y, mask = |t| > 0
        at, x, y = random_case(512, 20, seed=5)
        t, u, mask = dual_prox_sweep(at, x, y, 0.9, 0.0, 0.0)
        np.testing.assert_allclose(u, t, rtol=1e-6)
        expected = x - 0.9 * (at @ y)
        np.testing.assert_allclose(t, expected, rtol=1e-4, atol=1e-4)
        assert np.all(np.asarray(mask) == (np.abs(np.asarray(t)) > 0))

    def test_zero_y_keeps_x_dependency_only(self):
        at, x, _ = random_case(512, 8, seed=6)
        y = np.zeros(8, np.float32)
        t, u, mask = dual_prox_sweep(at, x, y, 2.0, 0.5, 0.25)
        np.testing.assert_allclose(t, x, atol=1e-6)
        np.testing.assert_allclose(
            u, np.asarray(ref.prox_enet(jnp.asarray(x), 2.0, 0.5, 0.25)), atol=1e-6
        )
        assert np.all(np.asarray(mask) == (np.abs(x) > 1.0))


class TestProxClosedForms:
    """The jnp oracle itself vs the paper's closed forms (f64 for exactness)."""

    def test_prox_branches(self):
        # sigma=lam1=lam2=1: prox(t) = (t -/+ 1)/2 outside [-1, 1], 0 inside
        t = jnp.asarray([3.0, -3.0, 0.3, 1.0, -1.0], jnp.float64)
        u = ref.prox_enet(t, 1.0, 1.0, 1.0)
        np.testing.assert_allclose(u, [1.0, -1.0, 0.0, 0.0, 0.0])

    def test_moreau_identity(self):
        # x = prox_{sigma p}(x) + sigma * prox_{p*/sigma}(x/sigma)
        x = jnp.linspace(-5, 5, 201)
        sigma, lam1, lam2 = 0.8, 1.2, 0.6
        lhs = ref.prox_enet(x, sigma, lam1, lam2) + sigma * ref.prox_enet_conj(
            x, sigma, lam1, lam2
        )
        np.testing.assert_allclose(lhs, x, rtol=1e-6, atol=1e-6)

    def test_conjugate_matches_proposition1(self):
        z = jnp.asarray([2.0, 0.5, -3.0])
        # lam1=lam2=1: p*(2)=0.5, p*(0.5)=0, p*(-3)=2
        assert abs(float(ref.enet_conjugate(z[:1], 1.0, 1.0)) - 0.5) < 1e-6
        assert float(ref.enet_conjugate(z[1:2], 1.0, 1.0)) == 0.0
        assert abs(float(ref.enet_conjugate(z[2:], 1.0, 1.0)) - 2.0) < 1e-6

    def test_fenchel_young(self):
        lam1, lam2 = 1.1, 0.7
        xs = jnp.linspace(-3, 3, 61)
        zs = jnp.linspace(-3, 3, 61)
        for xv in xs:
            p = ref.enet_penalty(xv[None], lam1, lam2)
            pstar = ref.enet_conjugate(zs, lam1, lam2)  # not per-z; do per-z below
        # per-(x, z) check on a coarse grid
        for xv in np.linspace(-3, 3, 13):
            for zv in np.linspace(-3, 3, 13):
                lhs = float(
                    ref.enet_penalty(jnp.asarray([xv]), lam1, lam2)
                    + ref.enet_conjugate(jnp.asarray([zv]), lam1, lam2)
                )
                assert lhs >= xv * zv - 1e-9

    def test_prox_conj_is_gradient_consistent(self):
        # For z = prox_{p*/sigma}(t/sigma):  t/sigma - z = grad p*(z)/sigma.
        sigma, lam1, lam2 = 1.5, 1.0, 2.0
        for tv in [-4.0, -1.5, 0.0, 1.4999, 1.5001, 4.0]:
            t = jnp.asarray(tv, jnp.float64)
            z = ref.prox_enet_conj(t, sigma, lam1, lam2)
            grad_pstar = ref.soft_threshold(z, lam1) / lam2
            np.testing.assert_allclose(
                float(t / sigma - z), float(grad_pstar / sigma), atol=1e-10
            )


class TestPerfEstimators:
    def test_vmem_budget_within_tpu_limits(self):
        # the default tile at the bench shape must fit VMEM with 2x buffering
        assert vmem_bytes(DEFAULT_BLOCK_N, 500) * 2 < 16 * 2**20

    def test_mxu_estimate_bounds(self):
        assert 0.0 < mxu_utilization_estimate(512, 500) <= 1.0
        assert mxu_utilization_estimate(512, 128) == 1.0
